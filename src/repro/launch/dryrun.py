import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell on placeholder devices; capture memory/cost/collective statistics for
the roofline analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 cells x 2 meshes
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single

Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_arch
from repro.dist.sharding import (TRAIN_RULES, SERVE_RULES, MOE_SERVE_RULES,
                                 ShardingRules, param_partition_specs,
                                 set_rules, spec_for)
from repro.launch.mesh import make_production_mesh
from repro.models.api import (build_model, cache_specs, input_specs,
                              param_counts, shapes_and_logical)
from repro.train import adamw, adafactor, cosine_schedule, make_train_step
from repro.train.step import TrainState

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun"

from repro.launch.hlo import cost_dict, parse_collectives


def _opt_state_specs(opt_state_shapes, params_shapes, pspecs):
    """Optimizer-state PartitionSpecs: moments inherit the param spec;
    adafactor's factored vr/vc drop the last / second-to-last dim."""
    pflat, ptree = jax.tree.flatten(params_shapes)
    specflat = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    by_shape = {}

    def leaf_spec(leaf):
        # match a param leaf by shape identity (moments); factored stats match
        # a param whose shape starts with leaf.shape
        for p, s in zip(pflat, specflat):
            if p.shape == leaf.shape:
                return s
        for p, s in zip(pflat, specflat):
            if len(p.shape) == len(leaf.shape) + 1:
                if p.shape[:-1] == leaf.shape:       # vr: drop last
                    return P(*tuple(s)[:-1])
                if p.shape[:-2] + p.shape[-1:] == leaf.shape:  # vc
                    return P(*(tuple(s)[:-2] + tuple(s)[-1:]))
        return P()

    return jax.tree.map(leaf_spec, opt_state_shapes)


def run_cell(arch: str, shape: str, multi_pod: bool, save: bool = True,
             variant: str = "baseline"):
    from repro.dist.sharding import VARIANTS, ShardingRules
    import dataclasses
    rule_over, cfg_over = VARIANTS[variant]
    mod = get_arch(arch)
    skip = getattr(mod, "SKIPS", {}).get(shape)
    mesh_name = ("multi" if multi_pod else "single") + \
        ("" if variant == "baseline" else f"+{variant}")
    if skip:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "skip", "reason": skip}
        _save(rec)
        print(f"[SKIP] {arch} x {shape}: {skip}")
        return rec

    cfg = dataclasses.replace(mod.CONFIG, **cfg_over)
    kind, seq, batch = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    model = build_model(cfg)
    pshapes, logical = shapes_and_logical(cfg)

    big_moe = cfg.family == "moe"
    if kind == "train":
        rules = TRAIN_RULES
    elif big_moe:
        rules = MOE_SERVE_RULES
    else:
        rules = SERVE_RULES
    rules = ShardingRules({**rules, **rule_over})

    pspecs = param_partition_specs(pshapes, logical, rules, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    repl = NamedSharding(mesh, P())

    specs = input_specs(cfg, kind, seq, batch)

    def in_sh(name, s):
        if name in ("tokens", "labels"):
            return NamedSharding(mesh, spec_for(
                s.shape, ("batch", None), rules, mesh))
        if name == "positions":
            lg = (None, "batch", None) if len(s.shape) == 3 else ("batch", None)
            return NamedSharding(mesh, spec_for(s.shape, lg, rules, mesh))
        if name == "frames":
            return NamedSharding(mesh, spec_for(
                s.shape, ("batch", "act_seq", None), rules, mesh))
        if name in ("token", "pos"):
            return NamedSharding(mesh, spec_for(s.shape, ("batch",), rules,
                                                mesh))
        if name == "enc_out":
            return NamedSharding(mesh, spec_for(
                s.shape, ("batch", None, None), rules, mesh))
        return repl
    batch_sh = {k: in_sh(k, v) for k, v in specs.items()}

    t0 = time.time()
    with set_rules(rules, mesh):
        if kind == "train":
            opt = adafactor(cosine_schedule(1e-4, 100, 10000)) if big_moe \
                else adamw(cosine_schedule(3e-4, 100, 10000))
            step_fn = make_train_step(model, opt)
            ost = jax.eval_shape(opt.init, pshapes)
            osp = _opt_state_specs(ost, pshapes, pspecs)
            osh = jax.tree.map(lambda s: NamedSharding(mesh, s), osp,
                               is_leaf=lambda x: isinstance(x, P))
            state_struct = TrainState(params=pshapes, opt_state=ost,
                                      step=jax.ShapeDtypeStruct((), jnp.int32))
            state_sh = TrainState(params=psh, opt_state=osh, step=repl)
            out_sh = (state_sh, {"loss": repl, "grad_norm": repl,
                                 "step": repl})
            fn = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                         out_shardings=out_sh, donate_argnums=(0,))
            lowered = fn.lower(state_struct, specs)
        else:
            cspec = cache_specs(cfg, batch, seq)

            def cache_logical(leaf):
                n = len(leaf.shape)
                if n >= 4:  # kv caches (L, B, S, H, d) / (G, A, B, S, H, d)
                    lg = [None] * n
                    lg[-4] = "batch"
                    lg[-3] = "cache_seq"
                    lg[-2] = "kv_heads"
                    return P(*spec_for(leaf.shape, lg, rules, mesh))
                if n >= 2:
                    lg = [None] * n
                    lg[1 if n > 2 else 0] = "batch" if n <= 3 else None
                    return spec_for(leaf.shape, [None] * n, rules, mesh)
                return P()

            cspecs_p = jax.tree.map(cache_logical, cspec)
            csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs_p,
                               is_leaf=lambda x: isinstance(x, P))
            if kind == "prefill":
                fn = jax.jit(model.prefill,
                             in_shardings=(psh, batch_sh, csh),
                             out_shardings=(NamedSharding(mesh, spec_for(
                                 (batch, cfg.vocab), ("batch", "vocab"),
                                 rules, mesh)), csh),
                             donate_argnums=(2,))
            else:
                fn = jax.jit(model.decode,
                             in_shardings=(psh, batch_sh, csh),
                             out_shardings=(NamedSharding(mesh, spec_for(
                                 (batch, cfg.vocab), ("batch", "vocab"),
                                 rules, mesh)), csh),
                             donate_argnums=(2,))
            lowered = fn.lower(pshapes, specs, cspec)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_dict(compiled)
    cbytes, ccounts = parse_collectives(compiled.as_text())
    tot, act = param_counts(cfg)

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok",
        "variant": variant,
        "kind": kind, "seq": seq, "batch": batch, "chips": chips,
        "params_total": int(tot), "params_active": int(act),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)) if cost else None,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)) if cost else None,
        "memory": {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "alias_size_in_bytes",
                    "generated_code_size_in_bytes")
                   if mem is not None and hasattr(mem, k)},
        "collective_bytes": cbytes, "collective_counts": ccounts,
    }
    if save:
        _save(rec)
    mm = rec["memory"].get("argument_size_in_bytes", 0) + \
        rec["memory"].get("temp_size_in_bytes", 0)
    print(f"[OK] {arch} x {shape} x {mesh_name}: compile {t_compile:.0f}s, "
          f"flops/dev {rec['flops']:.3g}, args+temp/dev {mm/2**30:.2f} GiB, "
          f"coll {sum(cbytes.values())/2**20:.1f} MiB")
    return rec


def _save(rec):
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    p.write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for a, s in cells:
        for mp in meshes:
            try:
                run_cell(a, s, mp, variant=args.variant)
            except Exception as e:  # noqa: BLE001 — report and continue
                traceback.print_exc()
                failures.append((a, s, mp, str(e)[:200]))
                _save({"arch": a, "shape": s,
                       "mesh": "multi" if mp else "single",
                       "status": "fail", "error": str(e)[:500]})
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nAll dry-run cells compiled.")


if __name__ == "__main__":
    main()
