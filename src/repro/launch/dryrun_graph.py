import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN technique at pod scale — three modes, all
driven through the unified ``repro.api.GraphStore`` front door:

* ``--mode ingest`` (default): the ShardedStore's distributed ingestion
  program (vertex-space sharding, routed batched edge ops) lowered on
  256/512-shard meshes;
* ``--mode analytics``: registered mesh analytics — BFS and PageRank by
  default, ``--algs wcc,sssp,bc`` for the full registry — compiled as one
  fused SPMD program each; ``--incremental`` additionally lowers each
  algorithm's warm-advance form (the epoch-delta incremental program,
  seeded from a previous epoch's values) as ``<alg>__advance``;
* ``--mode serve``: actually RUNS a small mixed read/write workload through
  ``serve.graph_service`` on placeholder shards and records throughput;
* ``--mode persist``: actually RUNS a durable ingest (WAL + epoch
  checkpoints via ``repro.storage``) on a sharded store, kills the store
  object, recovers from disk, and records throughput, checkpoint/WAL
  footprint, recovery wall time and bit-exactness.

Collective-byte totals count conditional (compacted/dense fallback)
branches at the TAKEN-BRANCH UPPER BOUND (max-bytes branch, never the
sum) — see ``launch.hlo.BRANCH_RULE``, recorded in every artifact.

  PYTHONPATH=src python -m repro.launch.dryrun_graph [--shards 256]
      [--mode ingest|analytics|serve] [--batch-per-shard 4096] [--no-pack]
"""
import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import make_store
from repro.launch.hlo import BRANCH_RULE, cost_dict, parse_collectives

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun"


def _record(name: str, rec: dict):
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / name).write_text(json.dumps(rec, indent=1))


def _compile_stats(compiled, dt: float) -> dict:
    mem = compiled.memory_analysis()
    cost = cost_dict(compiled)
    cb, cc = parse_collectives(compiled.as_text())
    return {
        "status": "ok", "kind": "graph",
        "flops": float(cost.get("flops", 0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0)),
        "memory": {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "temp_size_in_bytes",
                    "output_size_in_bytes", "alias_size_in_bytes")
                   if hasattr(mem, k)},
        "collective_bytes": cb, "collective_counts": cc,
        "collective_branch_rule": BRANCH_RULE,
        "compile_s": round(dt, 1),
    }


def _make_store(args, n):
    return make_store(
        "sharded", n_shards=n, n_per_shard=args.n_per_shard,
        expected_n=args.n_per_shard, sort_capacity_factor=4.0,
        pool_blocks=args.n_per_shard // 2, block_size=16, k_max=256,
        dmax=4096, batch=args.batch_per_shard * n,
        m_cap=args.n_per_shard * 4, pack=not args.no_pack,
        route_budget=args.route_budget,
        frontier_budget=args.frontier_budget)


def _mode_ingest(args, store, n):
    B = store.batch
    K = args.pipeline_depth
    t0 = time.time()
    if K > 1:
        # the K-batch pipelined entry: one donated scan program over a
        # stacked (K, B, ...) super-batch — ``alias_size_in_bytes`` in the
        # memory analysis records the state bytes reusing the input image
        fn = store.apply_program(donate=True, depth=K)
        compiled = fn.lower(
            store.state_struct(),
            jax.ShapeDtypeStruct((K, B, 2), jnp.uint32),
            jax.ShapeDtypeStruct((K, B, 2), jnp.uint32),
            jax.ShapeDtypeStruct((K, B), jnp.float32),
            jax.ShapeDtypeStruct((K, B), bool)).compile()
    else:
        fn = store.apply_program(donate=True)
        compiled = fn.lower(
            store.state_struct(),
            jax.ShapeDtypeStruct((B, 2), jnp.uint32),
            jax.ShapeDtypeStruct((B, 2), jnp.uint32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), bool)).compile()
    tag = ("" if not args.no_pack else "+nopack") + \
        ("" if args.route_budget is None else f"+route{args.route_budget}") + \
        ("" if K == 1 else f"+pipe{K}")
    rec = {
        "arch": "radixgraph-ingest", "shape": f"ops{K * B}",
        "mesh": f"graph{n}" + tag,
        "chips": n, "batch_ops": K * B, "pipeline_depth": K,
        **_compile_stats(compiled, time.time() - t0),
    }
    name = f"radixgraph-ingest__{n}shards" + tag.replace("+", "__") + ".json"
    _record(name, rec)
    per_dev = sum(rec["collective_bytes"].values())
    print(f"[OK] graph-ingest x {n} shards (pack={not args.no_pack}, "
          f"K={K}): compile {rec['compile_s']:.0f}s, {K * B} ops/step, coll "
          f"{per_dev/2**20:.2f} MiB/dev "
          f"({sum(rec['collective_counts'].values()):.0f} launches), "
          f"args+temp {sum(rec['memory'].values())/2**30:.2f} GiB")
    return rec


def _mode_analytics(args, store, n):
    state_struct = store.state_struct()
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    keys_struct = jax.ShapeDtypeStruct((16, 2), jnp.uint32)
    fb = args.frontier_budget
    # per-alg (static knobs, dynamic-arg structs) — all registry entries
    catalog = {
        "bfs": (dict(max_iters=16), (state_struct, key_struct)),
        "pagerank": (dict(iters=8), (state_struct,)),
        "wcc": (dict(max_iters=16), (state_struct,)),
        "sssp": (dict(max_iters=16), (state_struct, key_struct)),
        "bc": (dict(max_depth=8), (state_struct, keys_struct)),
    }
    # warm-advance forms (--incremental): static knobs, extra dynamic-arg
    # structs, and the per-row warm value dtype the program is seeded with
    # (PageRank needs a tolerance — its fixed-iteration form has no warm
    # program by design)
    warm_catalog = {
        "bfs": (dict(max_iters=16), (key_struct,), jnp.int32),
        "pagerank": (dict(iters=8, damping=0.85, tol=1e-6), (),
                     jnp.float32),
        "wcc": (dict(max_iters=16), (), jnp.uint32),
        "sssp": (dict(max_iters=16), (key_struct,), jnp.float32),
    }
    recs = {}
    for alg_name in args.algs.split(","):
        static, in_structs = catalog[alg_name]
        t0 = time.time()
        compiled = store.analytics_program(alg_name, **static).lower(
            *in_structs).compile()
        recs[alg_name] = _compile_stats(compiled, time.time() - t0)
        if not args.incremental or alg_name not in warm_catalog:
            continue
        wstatic, wdyn, vdt = warm_catalog[alg_name]
        n_cap = state_struct.vt.del_time.shape[-1]
        t0 = time.time()
        compiled = store.warm_program(alg_name, **wstatic).lower(
            state_struct, *wdyn,
            jax.ShapeDtypeStruct((n, n_cap), vdt)).compile()
        recs[alg_name + "__advance"] = _compile_stats(
            compiled, time.time() - t0)
    tag = ("" if fb is None else f"__frontier{fb}") + \
        ("__incremental" if args.incremental else "")
    rec = {
        "arch": "radixgraph-analytics", "shape": f"mcap{store.m_cap}",
        "mesh": f"graph{n}" + ("" if fb is None else f"+frontier{fb}"),
        "chips": n, "m_cap": store.m_cap, "frontier_budget": fb,
        "status": "ok", "kind": "graph", "algs": recs,
        "collective_branch_rule": BRANCH_RULE,
    }
    _record(f"radixgraph-analytics__{n}shards{tag}.json", rec)
    for a, r in recs.items():
        per_dev = sum(r["collective_bytes"].values())
        print(f"[OK] graph-{a} x {n} shards: compile {r['compile_s']:.0f}s, "
              f"coll {per_dev/2**20:.2f} MiB/dev "
              f"({sum(r['collective_counts'].values()):.0f} launches)")
    return rec


def _mode_serve(args, n):
    # real execution (placeholder devices): a small Fig.-11-style mixed
    # read/write stream through the query service, epochs sealed per step
    # (builds its own service-sized store; the compile-mode store params
    # --batch-per-shard/--route-budget do not apply here)
    from repro.serve.graph_service import (GraphQueryService,
                                           drive_mixed_workload)
    rng = np.random.default_rng(0)
    n_v, n_e = 1024, 8192
    ids = rng.choice(2 ** 32, n_v, replace=False).astype(np.uint64)
    src, dst = rng.choice(ids, n_e), rng.choice(ids, n_e)
    w = rng.uniform(0.5, 2, n_e).astype(np.float32)
    svc_store = make_store(
        "sharded", n_shards=n, n_per_shard=8192, expected_n=4096,
        pool_blocks=16384, block_size=16, dmax=2048, k_max=128,
        batch=512 * n, query_batch=128 * n)
    svc = GraphQueryService(svc_store)
    dt, reads = drive_mixed_workload(svc, src, dst, w, ids[:128 * n])
    tb = svc.submit_query("bfs", source=int(src[0]))
    svc.run()
    bfs_answer = svc.claim(tb)
    rec = {
        "arch": "radixgraph-serve", "shape": f"ops{n_e}",
        "mesh": f"graph{n}", "chips": n, "status": "ok", "kind": "graph",
        "write_ops_per_s": round(n_e / dt, 1),
        "read_q_per_s": round(reads / dt, 1),
        "epochs_sealed": svc.stats["epochs_sealed"],
        "ops_dropped": svc.stats["ops_dropped"],
        "bfs_reached": sum(1 for v in bfs_answer.values() if v >= 0),
    }
    _record(f"radixgraph-serve__{n}shards.json", rec)
    print(f"[OK] graph-serve x {n} shards: {rec['write_ops_per_s']:.0f} "
          f"write ops/s, {rec['read_q_per_s']:.0f} reads/s, "
          f"{rec['epochs_sealed']} epochs, dropped {rec['ops_dropped']}")
    return rec


def _mode_persist(args, n):
    # real execution (placeholder devices): durable ingest through the
    # storage subsystem on a sharded store, then recovery from disk with
    # a bit-exactness check against the live store's epoch snapshot
    import shutil
    import tempfile

    from repro.api import OpBatch, ReadOp
    from repro.storage import DurableStore, recover

    def _graph_store():
        return make_store(
            "sharded", n_shards=n, n_per_shard=8192, expected_n=4096,
            pool_blocks=16384, block_size=16, dmax=2048, k_max=128,
            batch=512 * n, query_batch=128 * n)

    def _leaves(store):
        return [np.asarray(x) for x in
                jax.tree.leaves(store.read(ReadOp("snapshot")))]

    rng = np.random.default_rng(0)
    n_v, n_e = 1024, 8192
    ids = rng.choice(2 ** 32, n_v, replace=False).astype(np.uint64)
    src, dst = rng.choice(ids, n_e), rng.choice(ids, n_e)
    w = rng.uniform(0.5, 2, n_e).astype(np.float32)
    B = 512 * n

    # WAL-off reference load of the same stream (the durability tax's
    # denominator at this scale)
    t0 = time.time()
    ref = _graph_store()
    for lo in range(0, n_e, B):
        ref.apply(OpBatch.edges(src[lo:lo + B], dst[lo:lo + B],
                                w[lo:lo + B]))
    bulk_s = time.time() - t0
    live_edges = ref.read(ReadOp("num_edges"))

    workdir = tempfile.mkdtemp(prefix="dryrun_persist_")
    store = DurableStore(_graph_store(), workdir, group_commit=32,
                         checkpoint_every=3)
    t0 = time.time()
    for lo in range(0, n_e, B):
        store.apply(OpBatch.edges(src[lo:lo + B], dst[lo:lo + B],
                                  w[lo:lo + B]))
    store.sync()          # durable-ack boundary, in the timed region
    dt = time.time() - t0
    stats = dict(store.stats)
    live = _leaves(store)
    store.close()
    del store

    t0 = time.time()
    rec_store, report = recover(workdir, _graph_store)
    recover_s = time.time() - t0
    bit_exact = (rec_store.read(ReadOp("num_edges")) == live_edges and
                 all(np.array_equal(a, b)
                     for a, b in zip(live, _leaves(rec_store))))
    rec_store.close()
    shutil.rmtree(workdir, ignore_errors=True)

    rec = {
        "arch": "radixgraph-persist", "shape": f"ops{n_e}",
        "mesh": f"graph{n}", "chips": n, "status": "ok", "kind": "graph",
        "write_ops_per_s": round(n_e / dt, 1),
        "checkpoints_written": stats["checkpoints"],
        "last_checkpoint_kind": stats["last_checkpoint_kind"],
        "checkpoint_bytes": stats["checkpoint_bytes"],
        "wal_records": stats["wal_records"],
        "wal_bytes": stats["wal_bytes"],
        "recover_s": round(recover_s, 2),
        "recovered_checkpoint_kind": report["checkpoint_kind"],
        "replayed_records": report["replayed"],
        "recovery_bit_exact": bool(bit_exact),
        "bulk_load_s": round(bulk_s, 2),
        "bulk_edges_live": int(live_edges),
        "durable_vs_bulk": round(bulk_s / dt, 2),
    }
    _record(f"radixgraph-persist__{n}shards.json", rec)
    print(f"[OK] graph-persist x {n} shards: {rec['write_ops_per_s']:.0f} "
          f"write ops/s ({rec['durable_vs_bulk']:.2f}x of WAL-off), "
          f"{rec['checkpoints_written']} ckpts "
          f"(last {rec['last_checkpoint_kind']}, "
          f"{rec['checkpoint_bytes']} B), recover {rec['recover_s']}s "
          f"({rec['recovered_checkpoint_kind']} + "
          f"{rec['replayed_records']} replayed), "
          f"bit_exact={rec['recovery_bit_exact']}")
    assert bit_exact, "persist dryrun: recovery diverged from live state"
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=256)
    ap.add_argument("--mode",
                    choices=("ingest", "analytics", "serve", "persist"),
                    default="ingest")
    ap.add_argument("--batch-per-shard", type=int, default=4096)
    ap.add_argument("--n-per-shard", type=int, default=1 << 17)
    ap.add_argument("--no-pack", action="store_true")
    ap.add_argument("--route-budget", type=int, default=None,
                    help="compacted op-router budget (ingest mode)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="ingest mode: K batches fused per device program "
                         "(the lax.scan super-batch entry)")
    ap.add_argument("--frontier-budget", type=int, default=None,
                    help="compacted frontier/inflow exchange budget "
                         "(analytics mode)")
    ap.add_argument("--algs", default="bfs,pagerank",
                    help="analytics mode: comma list from the registry "
                         "(bfs,pagerank,wcc,sssp,bc)")
    ap.add_argument("--incremental", action="store_true",
                    help="analytics mode: also lower each algorithm's "
                         "warm-advance mesh program (epoch-delta "
                         "incremental form), recorded as <alg>__advance")
    args = ap.parse_args(argv)

    n = args.shards
    if args.mode == "serve":
        return _mode_serve(args, n)
    if args.mode == "persist":
        return _mode_persist(args, n)
    store = _make_store(args, n)
    return {"ingest": _mode_ingest,
            "analytics": _mode_analytics}[args.mode](args, store, n)


if __name__ == "__main__":
    main()
