import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN technique at pod scale: distributed RadixGraph
ingestion (vertex-space sharding, routed batched edge ops) on 256/512-shard
meshes. This is the third §Perf hillclimb cell.

  PYTHONPATH=src python -m repro.launch.dryrun_graph [--shards 256]
      [--batch-per-shard 4096] [--no-pack]
"""
import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.core import edgepool as ep
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import optimize_sort
from repro.dist.graph_engine import make_apply_edges, make_sharded_state
from repro.launch.hlo import cost_dict, parse_collectives

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=256)
    ap.add_argument("--batch-per-shard", type=int, default=4096)
    ap.add_argument("--n-per-shard", type=int, default=1 << 17)
    ap.add_argument("--no-pack", action="store_true")
    args = ap.parse_args(argv)

    n = args.shards
    mesh = jax.make_mesh((n,), ("data",), devices=jax.devices()[:n],
                         axis_types=(AxisType.Auto,))
    cfg = optimize_sort(args.n_per_shard, 32, 5)
    sspec = SortSpec.from_config(cfg, args.n_per_shard,
                                 capacity_factor=4.0)
    pspec = ep.PoolSpec(n_blocks=args.n_per_shard // 2, block_size=16,
                        k_max=256, dmax=4096)
    B = args.batch_per_shard * n

    state_struct = jax.eval_shape(
        lambda: make_sharded_state(sspec, pspec, n, args.n_per_shard))
    apply_fn = make_apply_edges(sspec, pspec, mesh, "data",
                                pack=not args.no_pack)
    fn = jax.jit(apply_fn, donate_argnums=(0,))

    t0 = time.time()
    lowered = fn.lower(
        state_struct,
        jax.ShapeDtypeStruct((B, 2), jnp.uint32),
        jax.ShapeDtypeStruct((B, 2), jnp.uint32),
        jax.ShapeDtypeStruct((B,), jnp.float32),
        jax.ShapeDtypeStruct((B,), bool))
    compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    cost = cost_dict(compiled)
    cb, cc = parse_collectives(compiled.as_text())
    rec = {
        "arch": "radixgraph-ingest", "shape": f"ops{B}",
        "mesh": f"graph{n}" + ("" if not args.no_pack else "+nopack"),
        "status": "ok", "kind": "graph", "chips": n, "batch_ops": B,
        "flops": float(cost.get("flops", 0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0)),
        "memory": {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "temp_size_in_bytes")
                   if hasattr(mem, k)},
        "collective_bytes": cb, "collective_counts": cc,
        "compile_s": round(dt, 1),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    name = f"radixgraph-ingest__{n}shards" + \
        ("" if not args.no_pack else "__nopack") + ".json"
    (RESULTS / name).write_text(json.dumps(rec, indent=1))
    per_dev = sum(cb.values())
    print(f"[OK] graph-ingest x {n} shards (pack={not args.no_pack}): "
          f"compile {dt:.0f}s, {B} ops/step, coll {per_dev/2**20:.2f} "
          f"MiB/dev ({sum(cc.values()):.0f} launches), "
          f"args+temp {sum(rec['memory'].values())/2**30:.2f} GiB")
    return rec


if __name__ == "__main__":
    main()
