import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN technique at pod scale — three modes:

* ``--mode ingest`` (default): distributed RadixGraph ingestion (vertex-space
  sharding, routed batched edge ops) on 256/512-shard meshes;
* ``--mode analytics``: the versioned read path — per-shard CSR snapshot +
  level-synchronous BFS and PageRank with frontier/inflow exchange over the
  mesh axis, compiled as one fused SPMD program each;
* ``--mode serve``: actually RUNS a small mixed read/write workload through
  ``serve.graph_service`` on placeholder shards and records throughput.

  PYTHONPATH=src python -m repro.launch.dryrun_graph [--shards 256]
      [--mode ingest|analytics|serve] [--batch-per-shard 4096] [--no-pack]
"""
import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.core import edgepool as ep
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import optimize_sort
from repro.dist.graph_engine import (make_apply_edges, make_bfs,
                                     make_pagerank, make_sharded_state,
                                     make_sync_vertices)
from repro.launch.hlo import cost_dict, parse_collectives

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun"


def _record(name: str, rec: dict):
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / name).write_text(json.dumps(rec, indent=1))


def _compile_stats(compiled, dt: float) -> dict:
    mem = compiled.memory_analysis()
    cost = cost_dict(compiled)
    cb, cc = parse_collectives(compiled.as_text())
    return {
        "status": "ok", "kind": "graph",
        "flops": float(cost.get("flops", 0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0)),
        "memory": {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "temp_size_in_bytes")
                   if hasattr(mem, k)},
        "collective_bytes": cb, "collective_counts": cc,
        "compile_s": round(dt, 1),
    }


def _mode_ingest(args, mesh, sspec, pspec, n):
    B = args.batch_per_shard * n
    state_struct = jax.eval_shape(
        lambda: make_sharded_state(sspec, pspec, n, args.n_per_shard))
    apply_fn = make_apply_edges(sspec, pspec, mesh, "data",
                                pack=not args.no_pack,
                                route_budget=args.route_budget)
    fn = jax.jit(apply_fn, donate_argnums=(0,))
    t0 = time.time()
    compiled = fn.lower(
        state_struct,
        jax.ShapeDtypeStruct((B, 2), jnp.uint32),
        jax.ShapeDtypeStruct((B, 2), jnp.uint32),
        jax.ShapeDtypeStruct((B,), jnp.float32),
        jax.ShapeDtypeStruct((B,), bool)).compile()
    tag = ("" if not args.no_pack else "+nopack") + \
        ("" if args.route_budget is None else f"+route{args.route_budget}")
    rec = {
        "arch": "radixgraph-ingest", "shape": f"ops{B}",
        "mesh": f"graph{n}" + tag,
        "chips": n, "batch_ops": B,
        **_compile_stats(compiled, time.time() - t0),
    }
    name = f"radixgraph-ingest__{n}shards" + tag.replace("+", "__") + ".json"
    _record(name, rec)
    per_dev = sum(rec["collective_bytes"].values())
    print(f"[OK] graph-ingest x {n} shards (pack={not args.no_pack}): "
          f"compile {rec['compile_s']:.0f}s, {B} ops/step, coll "
          f"{per_dev/2**20:.2f} MiB/dev "
          f"({sum(rec['collective_counts'].values()):.0f} launches), "
          f"args+temp {sum(rec['memory'].values())/2**30:.2f} GiB")
    return rec


def _mode_analytics(args, mesh, sspec, pspec, n):
    m_cap = args.n_per_shard * 4
    state_struct = jax.eval_shape(
        lambda: make_sharded_state(sspec, pspec, n, args.n_per_shard))
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    fb = args.frontier_budget
    recs = {}
    for alg_name, build, in_structs in (
            ("bfs", lambda: make_bfs(sspec, pspec, mesh, "data", m_cap,
                                     max_iters=16, frontier_budget=fb),
             (state_struct, key_struct)),
            ("pagerank", lambda: make_pagerank(sspec, pspec, mesh, "data",
                                               m_cap, iters=8,
                                               frontier_budget=fb),
             (state_struct,))):
        t0 = time.time()
        compiled = jax.jit(build()).lower(*in_structs).compile()
        recs[alg_name] = _compile_stats(compiled, time.time() - t0)
    tag = "" if fb is None else f"__frontier{fb}"
    rec = {
        "arch": "radixgraph-analytics", "shape": f"mcap{m_cap}",
        "mesh": f"graph{n}" + ("" if fb is None else f"+frontier{fb}"),
        "chips": n, "m_cap": m_cap, "frontier_budget": fb,
        "status": "ok", "kind": "graph", "algs": recs,
    }
    _record(f"radixgraph-analytics__{n}shards{tag}.json", rec)
    for a, r in recs.items():
        per_dev = sum(r["collective_bytes"].values())
        print(f"[OK] graph-{a} x {n} shards: compile {r['compile_s']:.0f}s, "
              f"coll {per_dev/2**20:.2f} MiB/dev "
              f"({sum(r['collective_counts'].values()):.0f} launches)")
    return rec


def _mode_serve(args, mesh, sspec, pspec, n):
    # real execution (placeholder devices): a small Fig.-11-style mixed
    # read/write stream through the query service, epochs sealed per step
    from repro.serve.graph_service import (GraphQueryService,
                                           drive_mixed_workload)
    rng = np.random.default_rng(0)
    n_v, n_e = 1024, 8192
    ids = rng.choice(2 ** 32, n_v, replace=False).astype(np.uint64)
    src, dst = rng.choice(ids, n_e), rng.choice(ids, n_e)
    w = rng.uniform(0.5, 2, n_e).astype(np.float32)
    svc = GraphQueryService(
        n_shards=n, n_per_shard=8192, expected_n=4096, pool_blocks=16384,
        block_size=16, dmax=2048, k_max=128, write_batch=512 * n,
        query_batch=128 * n)
    dt, reads = drive_mixed_workload(svc, src, dst, w, ids[:128 * n])
    tb = svc.submit_query("bfs", source=int(src[0]))
    svc.run()
    bfs_answer = svc.claim(tb)
    rec = {
        "arch": "radixgraph-serve", "shape": f"ops{n_e}",
        "mesh": f"graph{n}", "chips": n, "status": "ok", "kind": "graph",
        "write_ops_per_s": round(n_e / dt, 1),
        "read_q_per_s": round(reads / dt, 1),
        "epochs_sealed": svc.stats["epochs_sealed"],
        "ops_dropped": svc.stats["ops_dropped"],
        "bfs_reached": sum(1 for v in bfs_answer.values() if v >= 0),
    }
    _record(f"radixgraph-serve__{n}shards.json", rec)
    print(f"[OK] graph-serve x {n} shards: {rec['write_ops_per_s']:.0f} "
          f"write ops/s, {rec['read_q_per_s']:.0f} reads/s, "
          f"{rec['epochs_sealed']} epochs, dropped {rec['ops_dropped']}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=256)
    ap.add_argument("--mode", choices=("ingest", "analytics", "serve"),
                    default="ingest")
    ap.add_argument("--batch-per-shard", type=int, default=4096)
    ap.add_argument("--n-per-shard", type=int, default=1 << 17)
    ap.add_argument("--no-pack", action="store_true")
    ap.add_argument("--route-budget", type=int, default=None,
                    help="compacted op-router budget (ingest mode)")
    ap.add_argument("--frontier-budget", type=int, default=None,
                    help="compacted frontier/inflow exchange budget "
                         "(analytics mode)")
    args = ap.parse_args(argv)

    n = args.shards
    mesh = jax.make_mesh((n,), ("data",), devices=jax.devices()[:n],
                         axis_types=(AxisType.Auto,))
    cfg = optimize_sort(args.n_per_shard, 32, 5)
    sspec = SortSpec.from_config(cfg, args.n_per_shard,
                                 capacity_factor=4.0)
    pspec = ep.PoolSpec(n_blocks=args.n_per_shard // 2, block_size=16,
                        k_max=256, dmax=4096)
    return {"ingest": _mode_ingest, "analytics": _mode_analytics,
            "serve": _mode_serve}[args.mode](args, mesh, sspec, pspec, n)


if __name__ == "__main__":
    main()
