"""Production mesh construction.

Importing this module never touches jax device state — meshes are built only
inside the factory functions.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devs)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this automatically)")
    return jax.make_mesh(shape, axes, devices=devs[:n],
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(model_axis: int = 1):
    """Debug mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
