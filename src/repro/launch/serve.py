"""Serving driver: batched requests through the continuous-batching engine
with the RadixKV (snapshot-log) block manager.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 16 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.api import build_model
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--smax", type=int, default=128)
    args = ap.parse_args(argv)

    mod = get_arch(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.CONFIG
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=args.slots, smax=args.smax)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(4, 17)).astype(np.int32)
               for _ in range(args.requests)]
    t0 = time.time()
    results = eng.run(prompts, max_new=args.max_new)
    dt = time.time() - t0
    tokens = sum(len(v) for v in results.values())
    print(f"[serve] {len(results)}/{args.requests} requests, {tokens} tokens "
          f"in {dt:.2f}s ({tokens/dt:.1f} tok/s); kv defrags: "
          f"{eng.kv.defrags}, utilization: {eng.kv.utilization:.2f}")
    assert len(results) == args.requests
    return results


if __name__ == "__main__":
    main()
