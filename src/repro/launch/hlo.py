"""Partitioned-HLO analysis: collective bytes with while-loop trip counts.

XLA's ``cost_analysis``/naive text scans count a while (lax.scan) body ONCE.
This parser splits the HLO module into computations, finds ``while`` ops,
extracts trip counts from their condition computations (the max integer
constant — lax.scan lowers to ``compare(iter, L)``), and multiplies each
body's collective bytes through the call graph. Shapes in partitioned HLO
are per-device, so totals are per-device bytes on the wire.

Conditionals (``lax.cond`` — e.g. the compacted-/dense-exchange fallback)
execute exactly ONE branch, so summing every branch would overstate wire
traffic. Each conditional contributes the single branch with the LARGEST
total collective bytes — a taken-branch upper bound (``BRANCH_RULE``),
tight whenever one branch dominates (the dense fallback), never the sum.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple


def cost_dict(compiled) -> Dict[str, float]:
    """Normalized ``compiled.cost_analysis()``: newer jax returns a dict,
    0.4.x returns a one-element list of dicts (and None on some backends)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# how conditional branches enter the totals (recorded in dryrun artifacts)
BRANCH_RULE = "taken-branch-upper-bound(max)"
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_BLOCK_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{")
_WHILE_RE = re.compile(r"while\(.*?\)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"(?:true_computation|false_computation|"
                        r"branch_computations=\{)[^,}]*")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    b = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b += n * _DTYPE_BYTES[dt]
    return b


def parse_collectives(hlo_text: str) -> Tuple[Dict[str, float],
                                              Dict[str, float]]:
    """Returns (bytes_by_collective, counts_by_collective), per device,
    with while-loop bodies multiplied by their trip counts."""
    # --- split into computations ---
    blocks: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{"):
            m = _BLOCK_START.match(s)
            if m:
                cur = m.group(1)
                blocks[cur] = []
                if s.startswith("ENTRY"):
                    entry = cur
                continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            blocks[cur].append(s)

    # --- per-block direct stats and child edges ---
    direct_b: Dict[str, Dict[str, float]] = {}
    direct_c: Dict[str, Dict[str, float]] = {}
    children: Dict[str, list] = {}
    branches: Dict[str, list] = {}   # per block: conditional branch groups
    trip_of: Dict[str, int] = {}

    for name, lines in blocks.items():
        db = {c: 0.0 for c in COLLECTIVES}
        dc = {c: 0.0 for c in COLLECTIVES}
        ch = []
        br = []
        for s in lines:
            m = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.*)", s)
            if not m:
                continue
            rest = m.group(1)
            for c in COLLECTIVES:
                cm = re.search(rf"^(.*?)\b{c}(-start)?\(", rest)
                if cm:
                    # result type — possibly a tuple "(u32[...], ...)", which
                    # a naive split at the first "(" would read as empty.
                    # Async *-start tuples are (operand alias, result) pairs:
                    # halve so the wire bytes aren't double-counted.
                    b = _shape_bytes(cm.group(1))
                    if cm.group(2) and cm.group(1).lstrip().startswith("("):
                        b /= 2
                    db[c] += b
                    dc[c] += 1
                    break
            if " while(" in rest or rest.startswith("while("):
                bm = _BODY_RE.search(rest)
                cm = _COND_RE.search(rest)
                trip = 1
                if cm and cm.group(1) in blocks:
                    consts = [int(x) for x in _CONST_RE.findall(
                        "\n".join(blocks[cm.group(1)]))]
                    trip = max(consts) if consts else 1
                if bm:
                    ch.append((bm.group(1), max(trip, 1)))
                if cm:
                    ch.append((cm.group(1), max(trip, 1)))
            for cm in _CALL_RE.finditer(rest):
                ch.append((cm.group(1), 1))
            # conditional branches: ONE executes — group them so the totals
            # take the max-bytes branch, not the sum of all branches
            group = [cm.group(1) for cm in re.finditer(
                r"(?:true_computation|false_computation)=%?([\w\.\-]+)",
                rest)]
            for cm in re.finditer(r"branch_computations=\{([^}]*)\}", rest):
                group += [b.strip().lstrip("%")
                          for b in cm.group(1).split(",")]
            if group:
                br.append(group)
        direct_b[name], direct_c[name] = db, dc
        children[name], branches[name] = ch, br

    # --- DFS with memo ---
    memo_b: Dict[str, Dict[str, float]] = {}
    memo_c: Dict[str, Dict[str, float]] = {}

    def total(name, stack=()):
        if name in memo_b:
            return memo_b[name], memo_c[name]
        if name not in direct_b or name in stack:
            z = {c: 0.0 for c in COLLECTIVES}
            return z, dict(z)
        tb = dict(direct_b[name])
        tc = dict(direct_c[name])
        for child, mult in children[name]:
            cb, cc = total(child, stack + (name,))
            for c in COLLECTIVES:
                tb[c] += mult * cb[c]
                tc[c] += mult * cc[c]
        for group in branches[name]:
            totals = [total(b, stack + (name,)) for b in group]
            bb, bc_ = max(totals, key=lambda t: sum(t[0].values()))
            for c in COLLECTIVES:
                tb[c] += bb[c]
                tc[c] += bc_[c]
        memo_b[name], memo_c[name] = tb, tc
        return tb, tc

    if entry is None:
        z = {c: 0.0 for c in COLLECTIVES}
        return z, dict(z)
    return total(entry)
