"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On-cluster it runs the full config on the production mesh; with --smoke it
runs the reduced config on the local device(s). Features: sharded params
(planner), microbatch accumulation, checkpoint/restart (atomic + async +
SIGTERM hook), deterministic data resume, straggler watchdog, graph-walk
data source (--data graph) fed by a live RadixGraph.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import Checkpointer, latest_step, restore_checkpoint, \
    save_checkpoint
from repro.configs import get_arch
from repro.data import GraphWalkStream, Prefetcher, TokenStream, shard_batch
from repro.dist.sharding import TRAIN_RULES, param_partition_specs, set_rules
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.api import build_model, shapes_and_logical
from repro.train import adamw, adafactor, cosine_schedule, init_train_state, \
    make_train_step
from repro.train.step import TrainState


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule-total", type=int, default=None,
                    help="cosine schedule horizon (default: --steps)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", choices=("synthetic", "graph"),
                    default="synthetic")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--step-timeout", type=float, default=300.0,
                    help="straggler watchdog: warn if a step exceeds this")
    args = ap.parse_args(argv)

    mod = get_arch(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.CONFIG
    model = build_model(cfg)
    mesh = make_production_mesh() if args.production_mesh else \
        make_local_mesh()
    rules = TRAIN_RULES

    horizon = args.schedule_total or max(args.steps, 21)
    opt = adamw(cosine_schedule(args.lr, 20, horizon))
    if cfg.family == "moe" and not args.smoke:
        opt = adafactor(cosine_schedule(args.lr, 20, horizon))
    step_fn = make_train_step(model, opt, accum=args.accum)

    pshapes, logical = shapes_and_logical(cfg)
    pspecs = param_partition_specs(pshapes, logical, rules, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))

    with set_rules(rules, mesh):
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        state = TrainState(
            params=jax.tree.map(jax.device_put, state.params, psh),
            opt_state=state.opt_state, step=state.step)
        train_step = jax.jit(step_fn, donate_argnums=(0,))

        # ---- data ----
        if args.data == "graph":
            from repro.core.radixgraph import RadixGraph
            g = RadixGraph(n_max=4096, expected_n=2048, batch=1024,
                           pool_blocks=8192, undirected=True)
            rng = np.random.default_rng(0)
            ids = rng.choice(2**31, 2048, replace=False).astype(np.uint64)
            g.add_edges(rng.choice(ids, 16384), rng.choice(ids, 16384))
            stream = GraphWalkStream(g, cfg.vocab, args.batch, args.seq)
        else:
            stream = TokenStream(cfg.vocab, args.batch, args.seq)

        # ---- restore ----
        start = 0
        ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        if ckpt and latest_step(args.ckpt_dir) is not None:
            tree, start, meta = restore_checkpoint(args.ckpt_dir, state)
            state = tree
            stream.restore(meta["stream"])
            print(f"[train] restored step {start}")
        if ckpt:
            ckpt.install_sigterm_hook(lambda: (state, int(state.step)))

        if start >= args.steps:
            print(f"[train] checkpoint step {start} >= --steps {args.steps}; "
                  "nothing to do")
            return []
        it = Prefetcher(stream, depth=2)
        losses = []
        for i in range(start, args.steps):
            batch = next(it)
            if args.accum > 1:
                batch = {k: v.reshape((args.accum, v.shape[0] // args.accum)
                                      + v.shape[1:])
                         for k, v in batch.items()}
            batch = shard_batch(batch, mesh)
            t0 = time.time()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if dt > args.step_timeout:
                print(f"[watchdog] step {i} took {dt:.1f}s "
                      f"(> {args.step_timeout}s) — straggler suspected")
            losses.append(loss)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save_async(state, i + 1,
                                {"stream": stream.state_for(i + 1)})
        if ckpt:
            ckpt.wait()
            save_checkpoint(args.ckpt_dir, state, args.steps,
                            {"stream": stream.state_for(args.steps)})
        it.close()
        print(f"[train] done. loss {losses[0]:.4f} -> {losses[-1]:.4f}")
        return losses


if __name__ == "__main__":
    main()
