from .checkpoint import (Checkpointer, save_checkpoint, restore_checkpoint,
                         latest_step)

__all__ = ["Checkpointer", "save_checkpoint", "restore_checkpoint",
           "latest_step"]
