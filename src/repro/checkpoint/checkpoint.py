"""Fault-tolerant checkpointing: atomic, async, elastic.

* atomic: writes go to ``step_<N>.tmp`` and are renamed only after fsync —
  a crash mid-save never corrupts the latest checkpoint;
* async: ``Checkpointer.save_async`` snapshots device arrays to host then
  writes on a worker thread (training continues);
* elastic: leaves are stored as full logical arrays + the saved mesh shape;
  ``restore`` re-shards onto whatever mesh/shardings the restoring job uses
  (checkpoint topology != restore topology is the normal case at scale);
* self-describing: a manifest carries the pytree structure, shapes, dtypes,
  step and a user metadata dict (data-stream state lives there so input
  pipelines resume deterministically);
* keep-last-k GC + SIGTERM hook (preemption-safe save).
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import signal
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_key_str(k) for k in path)
        out[key] = leaf
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save_checkpoint(directory, tree, step: int, metadata: Optional[Dict] = None,
                    keep: int = 3):
    """Synchronous atomic save of a pytree of (possibly sharded) arrays."""
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"step_{step}.tmp"
    final = d / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": int(step), "metadata": metadata or {},
                "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        arrays[key.replace("/", "__")] = arr
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    with open(tmp / "manifest.json", "rb+") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(d, keep)
    return str(final)


def _gc(d: pathlib.Path, keep: int):
    steps = sorted(int(m.group(1)) for p in d.iterdir()
                   if (m := re.fullmatch(r"step_(\d+)", p.name)))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(d / f"step_{s}", ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for p in d.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def restore_checkpoint(directory, target_tree, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``target_tree`` (pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional parallel pytree of
    NamedSharding — leaves are device_put with them (elastic re-shard).
    Returns (tree, step, metadata)."""
    d = pathlib.Path(directory)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {d}")
    src = d / f"step_{step}"
    manifest = json.loads((src / "manifest.json").read_text())
    data = np.load(src / "arrays.npz")

    flat_t = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves_t, treedef = jax.tree.flatten(target_tree)
    paths = [_SEP.join(_key_str(k) for k in path)
             for path, _ in flat_t[0]]
    sh_flat = (jax.tree.leaves(shardings,
                               is_leaf=lambda x: hasattr(x, "mesh"))
               if shardings is not None else [None] * len(paths))
    out = []
    for p, tgt, sh in zip(paths, leaves_t, sh_flat):
        key = p.replace("/", "__")
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = data[key]
        want = manifest["leaves"][p]
        assert list(arr.shape) == want["shape"], p
        if hasattr(tgt, "dtype"):
            arr = arr.astype(tgt.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out), step, manifest["metadata"]


class Checkpointer:
    """Async checkpointer with preemption (SIGTERM) hook."""

    def __init__(self, directory, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[Exception] = None
        self._preempt_tree = None
        self._preempt_step = None

    def save_async(self, tree, step: int, metadata=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.dir, host_tree, step, metadata,
                                self.keep)
            except Exception as e:  # noqa: BLE001
                self._last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    def install_sigterm_hook(self, get_state):
        """On SIGTERM (preemption), synchronously checkpoint and exit 0."""
        def handler(signum, frame):
            tree, step = get_state()
            save_checkpoint(self.dir, tree, step,
                            {"preempted": True}, self.keep)
            os._exit(0)

        signal.signal(signal.SIGTERM, handler)
