"""Train step: value_and_grad + microbatch accumulation + clip + optimizer.

The step is a pure function of (TrainState, batch) — jit/pjit it with the
shardings from the planner. Microbatch accumulation is a lax.scan over a
leading ``accum`` dim of the batch (keeps the per-microbatch FSDP
all-gathers overlapped with compute by the XLA scheduler).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .optimizer import Optimizer, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_train_state(model, optimizer: Optimizer, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model, optimizer: Optimizer, *, accum: int = 1,
                    max_grad_norm: float = 1.0,
                    grad_transform: Optional[Callable] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` leaves are (accum, mb, ...) when accum > 1, else (B, ...).
    ``grad_transform`` hooks gradient compression / custom reductions.
    """

    def loss_fn(params, mb):
        return model.train_loss(params, mb)

    def train_step(state: TrainState, batch):
        if accum > 1:
            def mb_step(gsum, mb):
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return gsum, l

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            gsum, losses = jax.lax.scan(mb_step, zeros, batch)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = jax.tree.map(lambda p, u: (p + u.astype(p.dtype)),
                              state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gnorm,
                           "step": new_state.step}

    return train_step
