"""Optimizers (pure-pytree): AdamW and Adafactor (factored second moment —
the memory-viable choice for the 0.8T/1T MoE cells), plus LR schedules.

API mirrors optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, state)``. Optimizer state
inherits the params' sharding (leaf-for-leaf identical shapes, or factored
vectors which XLA shards trivially).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.minimum(warm, cos)
    return lr


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), g


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          dtype=jnp.float32) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, dtype)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        lr = lr_fn(c)
        b1c = 1 - b1 ** c.astype(jnp.float32)
        b2c = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(dtype)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            u = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
            u = u + weight_decay * p.astype(dtype)
            return (-lr * u).astype(p.dtype), m, v

        gl, treedef = jax.tree.flatten(grads)
        ml = jax.tree.leaves(state["m"])
        vl = jax.tree.leaves(state["v"])
        pl = jax.tree.leaves(params)
        outs = [upd(g, m, v, p) for g, m, v, p in zip(gl, ml, vl, pl)]
        updates = treedef.unflatten([o[0] for o in outs])
        m = treedef.unflatten([o[1] for o in outs])
        v = treedef.unflatten([o[2] for o in outs])
        return updates, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


def adafactor(lr_fn, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern). Leaves with rank
    >= 2 factor the last two dims into row/col statistics — O(sum dims) state
    instead of O(prod dims); 1-D leaves fall back to full moments."""

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"s": jax.tree.map(st, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        lr = lr_fn(c)
        beta = 1.0 - c.astype(jnp.float32) ** (-decay)

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rms_r = vr / jnp.mean(vr, axis=-1, keepdims=True)
                u = g32 * jax.lax.rsqrt(rms_r + eps)[..., None] * \
                    jax.lax.rsqrt(vc + eps)[..., None, :]
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), ns

        gl, treedef = jax.tree.flatten(grads)
        sl = treedef.flatten_up_to(state["s"])
        pl = jax.tree.leaves(params)
        outs = [upd(g, s, p) for g, s, p in zip(gl, sl, pl)]
        updates = treedef.unflatten([o[0] for o in outs])
        s = treedef.unflatten([o[1] for o in outs])
        return updates, {"s": s, "count": c}

    return Optimizer(init, update)
