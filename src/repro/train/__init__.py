from .optimizer import adamw, adafactor, cosine_schedule
from .step import TrainState, make_train_step, init_train_state

__all__ = ["adamw", "adafactor", "cosine_schedule", "TrainState",
           "make_train_step", "init_train_state"]
