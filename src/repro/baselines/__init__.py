"""Baselines the paper compares against, on the same JAX substrate.

Vertex indices: JaxART (adaptive radix tree, 8-bit layers, sparse/dense
nodes), HashIndex (open-addressing — the multi-level-vector family's ID
translation), uniform-tree and vEB-tree SORT configurations (via
``sort_optimizer.uniform_config`` / ``veb_config`` + ``SortSpec``).

Edge structures: selected by ``RadixGraph(policy=...)`` — 'grow'
(log-structured, LiveGraph/GTX paradigm) and 'sorted' (sorted snapshot +
small buffer, Spruce paradigm) against the paper's 'snaplog'.
"""
from .art import JaxART
from .hash_index import HashIndex

__all__ = ["JaxART", "HashIndex"]
