"""JAX-ART: adaptive radix tree baseline (paper §2.1, Table 5, §4.7).

Faithful-in-spirit port of unodb-style ART to functional arrays: 8 bits per
layer; every node starts *sparse* (16-slot key+child arrays, linear scan —
models Node4/16) and metamorphoses to *dense* (256-slot pointer array —
models Node48/256) when it overflows. This reproduces the two effects the
paper measures: (1) scan cost on lookups through sparse nodes, (2)
resize/migrate cost on inserts — versus SORT's fixed-structure gathers.

Functional twist: node ids are stable; metamorphosis allocates a dense row
and flips a per-node mode bit (``dense_of`` indirection), so parents never
need re-pointing. The abandoned sparse row is accounted as freed.

Inserts are batched-sequential (lax.scan over keys) — matching the per-key
structural modification of pointer ARTs under a writer lock. Lookups are
fully vectorized.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.keys import pack_keys

SPARSE_CAP = 16


class ArtState(NamedTuple):
    skeys: Tuple[jnp.ndarray, ...]    # int32[cap_s, 16] radix bytes, -1 empty
    schild: Tuple[jnp.ndarray, ...]   # int32[cap_s, 16] child node id / offset
    dense_of: Tuple[jnp.ndarray, ...]  # int32[cap_s] dense row of node, -1 sparse
    dchild: Tuple[jnp.ndarray, ...]   # int32[cap_d, 256]
    scount: jnp.ndarray               # int32[l]
    dcount: jnp.ndarray               # int32[l]
    overflow: jnp.ndarray


@dataclass
class JaxART:
    """ART vertex index: ID -> int32 offset (-1 absent)."""

    n_max: int
    key_bits: int = 32
    dense_frac: float = 0.25  # dense-row capacity as a fraction of n_max

    def __post_init__(self):
        self.layers = (self.key_bits + 7) // 8
        cap_s = self.n_max + 2
        cap_d = max(64, int(self.n_max * self.dense_frac))
        l = self.layers
        self.state = ArtState(
            skeys=tuple(jnp.full((cap_s, SPARSE_CAP), -1, jnp.int32)
                        for _ in range(l)),
            schild=tuple(jnp.full((cap_s, SPARSE_CAP), -1, jnp.int32)
                         for _ in range(l)),
            dense_of=tuple(jnp.full((cap_s,), -1, jnp.int32)
                           for _ in range(l)),
            dchild=tuple(jnp.full((cap_d, 256), -1, jnp.int32)
                         for _ in range(l)),
            scount=jnp.zeros((l,), jnp.int32).at[0].set(1),  # root = node 0
            dcount=jnp.zeros((l,), jnp.int32),
            overflow=jnp.zeros((), jnp.int32),
        )

    def _bytes_of(self, keys):
        """(B, layers) radix bytes, MSB-aligned to key_bits."""
        out = []
        for i in range(self.layers):
            shift = max(self.key_bits - 8 * (i + 1), 0)
            if shift >= 32:
                b = (keys[:, 0] >> jnp.uint32(shift - 32)) & jnp.uint32(255)
            elif shift + 8 <= 32:
                b = (keys[:, 1] >> jnp.uint32(shift)) & jnp.uint32(255)
            else:
                lo_bits = 32 - shift
                b = (((keys[:, 0] & jnp.uint32((1 << (shift + 8 - 32)) - 1))
                      << jnp.uint32(lo_bits)) |
                     (keys[:, 1] >> jnp.uint32(shift))) & jnp.uint32(255)
            out.append(b.astype(jnp.int32))
        return jnp.stack(out, axis=1)

    def insert(self, ids, offsets):
        keys = pack_keys(np.asarray(ids, np.uint64), self.key_bits)
        radix = self._bytes_of(keys)
        self.state = _art_insert(self.layers, self.state, radix,
                                 jnp.asarray(offsets, jnp.int32))

    def lookup(self, ids):
        keys = pack_keys(np.asarray(ids, np.uint64), self.key_bits)
        radix = self._bytes_of(keys)
        return np.asarray(_art_lookup(self.layers, self.state, radix))

    def memory_bytes(self) -> int:
        s = int(np.asarray(self.scount_total()))
        d = int(np.asarray(self.state.dcount).sum())
        live_sparse = s - d  # metamorphosed sparse rows are freed
        # C-equivalent accounting: sparse = 16 key bytes + 16 ptrs (8B) = 144B
        # (unodb Node16); dense = 256 ptrs * 8B = 2 KiB (Node256)
        return live_sparse * (16 + 16 * 8) + d * 256 * 8

    def scount_total(self):
        return jnp.sum(self.state.scount)


@functools.partial(jax.jit, static_argnums=(0,))
def _art_lookup(layers: int, st: ArtState, radix: jnp.ndarray):
    B = radix.shape[0]
    node = jnp.zeros((B,), jnp.int32)
    valid = jnp.ones((B,), bool)
    for i in range(layers):
        b = radix[:, i]
        cap_s = st.skeys[i].shape[0]
        cap_d = st.dchild[i].shape[0]
        nc = jnp.clip(node, 0, cap_s - 1)
        drow = st.dense_of[i][nc]
        is_dense = drow >= 0
        dch = st.dchild[i][jnp.clip(drow, 0, cap_d - 1), b]
        sk = st.skeys[i][nc]
        hit = sk == b[:, None]
        pos = jnp.argmax(hit, axis=1)
        sch = jnp.where(jnp.any(hit, axis=1),
                        st.schild[i][nc, pos], -1)
        child = jnp.where(is_dense, dch, sch)
        child = jnp.where(valid, child, -1)
        valid = child >= 0
        node = jnp.maximum(child, 0)
    return jnp.where(valid, node, -1)


@functools.partial(jax.jit, static_argnums=(0,))
def _art_insert(layers: int, st: ArtState, radix: jnp.ndarray,
                offsets: jnp.ndarray):
    def insert_one(st: ArtState, xo):
        x, off = xo
        skeys, schild = list(st.skeys), list(st.schild)
        dense_of, dchild = list(st.dense_of), list(st.dchild)
        scount, dcount, overflow = st.scount, st.dcount, st.overflow

        node = jnp.int32(0)
        alive = jnp.bool_(True)
        for i in range(layers):
            b = x[i]
            cap_s = skeys[i].shape[0]
            cap_d = dchild[i].shape[0]
            nc = jnp.clip(node, 0, cap_s - 1)
            drow = dense_of[i][nc]
            is_dense = drow >= 0
            drc = jnp.clip(drow, 0, cap_d - 1)

            sk = skeys[i][nc]
            hit = sk == b
            has_s = jnp.any(hit)
            pos = jnp.argmax(hit)
            free = sk == -1
            has_free = jnp.any(free)
            fpos = jnp.argmax(free)

            child = jnp.where(is_dense, dchild[i][drc, b],
                              jnp.where(has_s, schild[i][nc, pos], -1))
            need = alive & (child < 0)

            is_leaf = i == layers - 1
            if is_leaf:
                new_child = off
            else:
                fits_s = scount[i + 1] < skeys[i + 1].shape[0]
                new_child = jnp.where(fits_s, scount[i + 1], -1)
                scount = scount.at[i + 1].add(jnp.where(need & fits_s, 1, 0))
                overflow = overflow + jnp.where(need & ~fits_s, 1, 0)
                need = need & fits_s

            # case A: dense node — direct store
            dchild[i] = dchild[i].at[
                jnp.where(need & is_dense, drc, cap_d), b
            ].set(new_child, mode="drop")

            # case B: sparse with free slot
            caseB = need & ~is_dense & has_free
            skeys[i] = skeys[i].at[jnp.where(caseB, nc, cap_s), fpos].set(
                b, mode="drop")
            schild[i] = schild[i].at[jnp.where(caseB, nc, cap_s), fpos].set(
                new_child, mode="drop")

            # case C: sparse full — metamorphose, migrate 16 entries, store
            caseC = need & ~is_dense & ~has_free
            new_did = dcount[i]
            fits_d = new_did < cap_d
            overflow = overflow + jnp.where(caseC & ~fits_d, 1, 0)
            caseC = caseC & fits_d
            mig_row = jnp.where(caseC, new_did, cap_d)
            mig_cols = jnp.where(sk >= 0, sk, 256)
            dchild[i] = dchild[i].at[mig_row, mig_cols].set(
                schild[i][nc], mode="drop")
            dchild[i] = dchild[i].at[mig_row, b].set(new_child, mode="drop")
            dense_of[i] = dense_of[i].at[jnp.where(caseC, nc, cap_s)].set(
                new_did, mode="drop")
            dcount = dcount.at[i].add(jnp.where(caseC, 1, 0))

            alive = alive & jnp.where(need, new_child >= 0, child >= 0)
            node = jnp.where(need, jnp.maximum(new_child, 0),
                             jnp.maximum(child, 0))
        return ArtState(tuple(skeys), tuple(schild), tuple(dense_of),
                        tuple(dchild), scount, dcount, overflow), 0

    st2, _ = jax.lax.scan(insert_one, st, (radix, offsets))
    return st2
