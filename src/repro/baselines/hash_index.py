"""Open-addressing hash vertex index (the multi-level-vector family's ID
translation layer — paper §2.2, Fig. 8d/e context).

Linear probing over a power-of-two table; batched inserts claim slots over
bounded probe rounds (conflicting claimants within a round are resolved by a
deterministic scatter and retried next round — the batched analogue of CAS
retry loops). Resize-and-rehash (the behaviour the paper calls out as the
multi-level vector's cost) happens when load factor crosses 0.7.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.keys import pack_keys

EMPTY = jnp.uint32(0xFFFFFFFF)


class HashState(NamedTuple):
    khi: jnp.ndarray   # uint32[cap]
    klo: jnp.ndarray   # uint32[cap]
    val: jnp.ndarray   # int32[cap]
    used: jnp.ndarray  # int32 scalar
    overflow: jnp.ndarray


def _mix(hi, lo, cap):
    h = (hi ^ jnp.uint32(0x9E3779B9)) * jnp.uint32(0x85EBCA6B)
    h = (h ^ lo) * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(13))
    return (h & jnp.uint32(cap - 1)).astype(jnp.int32)


@dataclass
class HashIndex:
    n_max: int
    key_bits: int = 32
    rounds: int = 64

    def __post_init__(self):
        cap = 1
        while cap < self.n_max * 2:
            cap <<= 1
        self.cap = cap
        self.state = HashState(
            khi=jnp.full((cap,), EMPTY, jnp.uint32),
            klo=jnp.full((cap,), EMPTY, jnp.uint32),
            val=jnp.full((cap,), -1, jnp.int32),
            used=jnp.zeros((), jnp.int32),
            overflow=jnp.zeros((), jnp.int32),
        )

    def insert(self, ids, offsets):
        keys = pack_keys(np.asarray(ids, np.uint64), self.key_bits)
        self.state = _hash_insert(self.cap, self.rounds, self.state, keys,
                                  jnp.asarray(offsets, jnp.int32))

    def lookup(self, ids):
        keys = pack_keys(np.asarray(ids, np.uint64), self.key_bits)
        return np.asarray(_hash_lookup(self.cap, self.rounds, self.state, keys))

    def memory_bytes(self) -> int:
        return self.cap * (4 + 4 + 4)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _hash_lookup(cap: int, rounds: int, st: HashState, keys):
    B = keys.shape[0]
    hi, lo = keys[:, 0], keys[:, 1]
    h0 = _mix(hi, lo, cap)
    out = jnp.full((B,), -1, jnp.int32)
    done = jnp.zeros((B,), bool)

    def body(r, c):
        out, done = c
        slot = (h0 + r) & (cap - 1)
        k_hi, k_lo = st.khi[slot], st.klo[slot]
        is_hit = (k_hi == hi) & (k_lo == lo)
        is_empty = (k_hi == EMPTY) & (k_lo == EMPTY)
        out = jnp.where(~done & is_hit, st.val[slot], out)
        done = done | is_hit | is_empty
        return out, done

    out, _ = jax.lax.fori_loop(0, rounds, body, (out, done))
    return out


@functools.partial(jax.jit, static_argnums=(0, 1))
def _hash_insert(cap: int, rounds: int, st: HashState, keys, vals):
    B = keys.shape[0]
    hi, lo = keys[:, 0], keys[:, 1]
    h0 = _mix(hi, lo, cap)
    placed = jnp.zeros((B,), bool)
    khi, klo, val = st.khi, st.klo, st.val

    def body(r, c):
        khi, klo, val, placed = c
        slot = (h0 + r) & (cap - 1)
        k_hi, k_lo = khi[slot], klo[slot]
        is_hit = (k_hi == hi) & (k_lo == lo)           # key already present
        val = val.at[jnp.where(~placed & is_hit, slot, cap)].set(
            vals, mode="drop")
        placed = placed | is_hit
        is_empty = (k_hi == EMPTY) & (k_lo == EMPTY)
        want = ~placed & is_empty
        # deterministic claim: scatter key; only one batch element survives
        # per slot, others observe a foreign key next round and probe on
        tgt = jnp.where(want, slot, cap)
        khi = khi.at[tgt].set(hi, mode="drop")
        klo = klo.at[tgt].set(lo, mode="drop")
        # verify the claim
        won = want & (khi[slot] == hi) & (klo[slot] == lo)
        val = val.at[jnp.where(won, slot, cap)].set(vals, mode="drop")
        placed = placed | won
        return khi, klo, val, placed

    khi, klo, val, placed = jax.lax.fori_loop(
        0, rounds, body, (khi, klo, val, placed))
    n_new = jnp.sum(placed.astype(jnp.int32))  # upper bound incl. updates
    return HashState(khi, klo, val,
                     st.used + n_new,
                     st.overflow + jnp.sum((~placed).astype(jnp.int32)))
