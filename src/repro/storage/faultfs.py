"""Deterministic fault injection for the durability subsystem.

Two families of faults:

* **in-flight** — a ``FaultInjector`` hooked into ``WalWriter`` kills the
  "process" (raises ``InjectedCrash``) after a configured number of
  records, optionally leaving a TORN tail: the first ``torn_bytes`` bytes
  of the failing record land on disk, byte-exactly what a crash between
  ``write`` and completion produces;
* **at-rest** — helpers that corrupt already-written files the way real
  storage fails: truncation (lost tail), bit flips (latent corruption),
  and deleted/partial checkpoint members (torn incremental chains).

Everything is seedable/deterministic so the recovery property tests can
enumerate failure points instead of sampling them.
"""
from __future__ import annotations

import pathlib
from typing import Optional, Tuple

__all__ = ["InjectedCrash", "FaultInjector", "truncate_file", "flip_byte",
           "corrupt_checkpoint_array", "tear_checkpoint"]


class InjectedCrash(RuntimeError):
    """Stands in for the process dying mid-write (kill -9, power loss)."""


class FaultInjector:
    """WAL writer hook: crash after ``fail_after_records`` appended
    records, tearing the failing record to ``torn_bytes`` bytes;
    ``fail_on_sync`` crashes at the next group-commit boundary instead
    (everything buffered, nothing torn)."""

    def __init__(self, fail_after_records: Optional[int] = None,
                 torn_bytes: int = 0, fail_on_sync: bool = False):
        self.fail_after_records = fail_after_records
        self.torn_bytes = int(torn_bytes)
        self.fail_on_sync = bool(fail_on_sync)
        self.records_seen = 0
        self.crashed = False

    def filter_record(self, seq: int, data: bytes) -> Tuple[bytes, bool]:
        self.records_seen += 1
        if (self.fail_after_records is not None
                and self.records_seen > self.fail_after_records):
            self.crashed = True
            return data[:max(0, min(self.torn_bytes, len(data)))], True
        return data, False

    def on_sync(self):
        if self.fail_on_sync:
            self.crashed = True
            raise InjectedCrash("injected crash at group-commit fsync")


def truncate_file(path, size: int):
    """Chop ``path`` to ``size`` bytes (lost tail)."""
    p = pathlib.Path(path)
    data = p.read_bytes()
    p.write_bytes(data[:max(0, size)])


def flip_byte(path, offset: int):
    """XOR one byte at ``offset`` (negative = from the end)."""
    p = pathlib.Path(path)
    data = bytearray(p.read_bytes())
    data[offset] ^= 0xFF
    p.write_bytes(bytes(data))


def _member_entry(man: dict, name: str) -> dict:
    entry = man["arrays"].get(name)
    if entry is None and man.get("delta"):
        entry = man["delta"]["arrays"].get(name) or \
            man["delta"]["arrays"].get("delta/" + name) or \
            (man["delta"]["blocks"] if name in ("blocks", "delta/blocks")
             else None)
    if entry is None:
        raise KeyError(f"no member {name!r} in checkpoint manifest")
    return entry


def corrupt_checkpoint_array(ckpt_dir, name: str, offset: int = -1):
    """Flip a byte inside a named array member of a checkpoint dir
    (name as recorded in the manifest, e.g. ``pool/dst`` — delta members
    resolve with or without their ``delta/`` prefix)."""
    import json
    d = pathlib.Path(ckpt_dir)
    man = json.loads((d / "manifest.json").read_text())
    flip_byte(d / _member_entry(man, name)["file"], offset)


def tear_checkpoint(ckpt_dir, name: Optional[str] = None):
    """Delete one member file of a checkpoint dir — the torn-directory
    failure a crash during (non-atomic) copy/backup tooling produces.
    Default: the manifest itself (worst case)."""
    d = pathlib.Path(ckpt_dir)
    if name is None:
        (d / "manifest.json").unlink()
        return
    import json
    man = json.loads((d / "manifest.json").read_text())
    (d / _member_entry(man, name)["file"]).unlink()
