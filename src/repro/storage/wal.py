"""Write-ahead log of applied ``OpBatch``es.

The paper's hybrid snapshot-log design maps directly onto disk: a sealed
epoch checkpoint is the snapshot, and the op stream is the log —
``GraphStore.apply`` is deterministic by construction (fixed-shape padded
batches, last-writer-wins within a batch), so replaying the EXACT applied
batches from a checkpointed state reproduces the live state bit for bit.
The WAL therefore frames batches at the store's apply boundary (never
re-split on replay: batch composition decides pool clocks and defrag
trigger points).

On-disk format (all little-endian):

* file preamble: ``b"RGWAL1\\x00\\x00"`` (8 bytes);
* record: ``magic u32 | seq u64 | kind u8 | len u32`` (17-byte header),
  ``crc u32`` over header-after-magic + payload, then the payload —
  a self-describing ``OpBatch`` encoding (kind + count + raw arrays).

Reading is TOLERANT by contract: ``read_wal`` returns the longest valid
record prefix plus a typed tail state (``core.status.Reason``) — a torn
tail (crash mid-write), a corrupt record, or lost framing never raises;
they terminate the scan exactly where durability ends. Writes are
fsync-batched: ``group_commit`` records per ``fsync`` (1 = every record
durable before ``append`` returns); ``sync()`` force-flushes the tail.

Fault injection: a ``faultfs.FaultInjector`` passed to ``WalWriter``
filters every record write (truncating it and/or raising
``InjectedCrash`` after the partial write lands), which is how the
recovery tests produce byte-exact torn tails deterministically.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import struct
import zlib
from typing import List, Optional, Tuple

import numpy as np

from repro.api.ir import OpBatch
from repro.core.status import Reason

__all__ = ["FILE_MAGIC", "REC_MAGIC", "encode_batch", "decode_batch",
           "encode_record", "WalRecord", "WalScan", "WalWriter",
           "read_wal", "wal_segments", "read_wal_dir"]

FILE_MAGIC = b"RGWAL1\x00\x00"
REC_MAGIC = 0x4C415752            # "RWAL"
_HDR = struct.Struct("<IQBI")     # magic, seq, kind, payload len
_CRC = struct.Struct("<I")
_KIND_CODE = {"edges": 0, "add_vertices": 1, "delete_vertices": 2}
_KIND_NAME = {v: k for k, v in _KIND_CODE.items()}


# ---- OpBatch payload codec ----

def encode_batch(batch: OpBatch) -> bytes:
    """Self-contained payload: ``n u32`` then the raw arrays (src/dst
    uint64 + weight float32, or ids uint64)."""
    n = len(batch)
    if batch.kind == "edges":
        return struct.pack("<I", n) + batch.src.tobytes() + \
            batch.dst.tobytes() + batch.weight.tobytes()
    return struct.pack("<I", n) + batch.ids.tobytes()


def decode_batch(kind_code: int, payload: bytes) -> OpBatch:
    """Inverse of ``encode_batch``; raises ``ValueError`` on any length
    mismatch (a CRC-valid but undecodable body is a format bug, surfaced
    as ``Reason.WAL_DECODE`` by the reader)."""
    kind = _KIND_NAME.get(kind_code)
    if kind is None:
        raise ValueError(f"unknown OpBatch kind code {kind_code}")
    if len(payload) < 4:
        raise ValueError("payload shorter than its count field")
    (n,) = struct.unpack_from("<I", payload)
    body = payload[4:]
    if kind == "edges":
        if len(body) != n * (8 + 8 + 4):
            raise ValueError("edges payload length mismatch")
        src = np.frombuffer(body[:8 * n], np.uint64)
        dst = np.frombuffer(body[8 * n:16 * n], np.uint64)
        w = np.frombuffer(body[16 * n:], np.float32)
        return OpBatch.edges(src.copy(), dst.copy(), w.copy())
    if len(body) != 8 * n:
        raise ValueError(f"{kind} payload length mismatch")
    ids = np.frombuffer(body, np.uint64).copy()
    return OpBatch(kind=kind, ids=ids)


def encode_record(seq: int, batch: OpBatch) -> bytes:
    payload = encode_batch(batch)
    hdr = _HDR.pack(REC_MAGIC, seq, _KIND_CODE[batch.kind], len(payload))
    crc = zlib.crc32(payload, zlib.crc32(hdr[4:]))
    return hdr + _CRC.pack(crc) + payload


# ---- reading ----

@dataclasses.dataclass(frozen=True)
class WalRecord:
    seq: int
    batch: OpBatch


@dataclasses.dataclass(frozen=True)
class WalScan:
    """Longest valid prefix of one segment (or one ordered segment set)."""

    records: List[WalRecord]
    tail: Reason              # OK, or why the scan stopped early
    valid_bytes: int          # offset of the first invalid byte

    @property
    def last_seq(self) -> int:
        return self.records[-1].seq if self.records else -1


def _scan(data: bytes) -> WalScan:
    if len(data) == 0:
        return WalScan([], Reason.OK, 0)
    if len(data) < len(FILE_MAGIC):
        return WalScan([], Reason.WAL_TORN, 0)
    if data[:len(FILE_MAGIC)] != FILE_MAGIC:
        return WalScan([], Reason.WAL_BAD_HEADER, 0)
    out: List[WalRecord] = []
    off = len(FILE_MAGIC)
    n = len(data)
    while off < n:
        if off + _HDR.size + _CRC.size > n:
            return WalScan(out, Reason.WAL_TORN, off)
        magic, seq, kcode, plen = _HDR.unpack_from(data, off)
        if magic != REC_MAGIC:
            return WalScan(out, Reason.WAL_BAD_MAGIC, off)
        body_at = off + _HDR.size + _CRC.size
        if body_at + plen > n:
            return WalScan(out, Reason.WAL_TORN, off)
        (crc,) = _CRC.unpack_from(data, off + _HDR.size)
        payload = data[body_at:body_at + plen]
        want = zlib.crc32(payload,
                          zlib.crc32(data[off + 4:off + _HDR.size]))
        if crc != want:
            return WalScan(out, Reason.WAL_BAD_CRC, off)
        try:
            batch = decode_batch(kcode, payload)
        except ValueError:
            return WalScan(out, Reason.WAL_DECODE, off)
        out.append(WalRecord(int(seq), batch))
        off = body_at + plen
    return WalScan(out, Reason.OK, off)


def read_wal(path) -> WalScan:
    """Scan one segment file; a missing file is an empty OK scan."""
    p = pathlib.Path(path)
    if not p.exists():
        return WalScan([], Reason.OK, 0)
    return _scan(p.read_bytes())


def wal_segments(directory) -> List[pathlib.Path]:
    """Segment files under ``directory``, ordered by start seq (segments
    rotate at checkpoints: ``wal_<start_seq>.log``)."""
    d = pathlib.Path(directory)
    if not d.exists():
        return []
    segs = []
    for p in d.glob("wal_*.log"):
        try:
            segs.append((int(p.stem.split("_", 1)[1]), p))
        except ValueError:
            continue
    return [p for _, p in sorted(segs)]


def read_wal_dir(directory, after_seq: int = -1) -> WalScan:
    """Ordered scan over every segment, stopping at the first non-OK
    tail (later segments are unreachable once durability is broken —
    rotation only ever happens after a durable checkpoint, so a torn
    middle segment means the later ones postdate a crash rollback).
    Returns records with ``seq > after_seq``."""
    records: List[WalRecord] = []
    tail = Reason.OK
    valid = 0
    for p in wal_segments(directory):
        scan = read_wal(p)
        records.extend(r for r in scan.records if r.seq > after_seq)
        valid += scan.valid_bytes
        if scan.tail is not Reason.OK:
            tail = scan.tail
            break
    return WalScan(records, tail, valid)


# ---- writing ----

class WalWriter:
    """Append-only segment writer with group-commit fsync.

    ``group_commit=k``: one ``fsync`` per ``k`` appended records (the
    classic group-commit latency/durability dial); ``fsync=False`` trusts
    the OS page cache (still ``flush``ed, so same-process readers see
    every byte). ``injector`` is the fault hook (see module docstring).
    """

    def __init__(self, path, *, group_commit: int = 32, fsync: bool = True,
                 injector=None):
        self.path = pathlib.Path(path)
        self.group_commit = max(1, int(group_commit))
        self.fsync = bool(fsync)
        self.injector = injector
        self.records_written = 0
        self.bytes_written = 0
        self.syncs = 0
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fresh = not self.path.exists() or self.path.stat().st_size == 0
        self._f = open(self.path, "ab")
        if fresh:
            self._f.write(FILE_MAGIC)
            self._flush(force=True)
        self._pending = 0

    def append(self, seq: int, batch: OpBatch) -> int:
        """Frame and append one applied batch; returns the record's byte
        size. Durability lags by up to ``group_commit - 1`` records."""
        data = encode_record(seq, batch)
        crash = False
        if self.injector is not None:
            data, crash = self.injector.filter_record(seq, data)
        self._f.write(data)
        if crash:
            # the torn bytes must actually land where a real crash would
            # leave them before the simulated process death propagates
            self._f.flush()
            os.fsync(self._f.fileno())
            from repro.storage.faultfs import InjectedCrash
            raise InjectedCrash(f"injected crash writing WAL seq {seq}")
        self.records_written += 1
        self.bytes_written += len(data)
        self._pending += 1
        if self._pending >= self.group_commit:
            self.sync()
        return len(data)

    def _flush(self, force: bool = False):
        self._f.flush()
        if self.fsync or force:
            os.fsync(self._f.fileno())

    def sync(self):
        """Force the group-commit boundary: flush + (configured) fsync."""
        if self.injector is not None:
            self.injector.on_sync()
        self._flush()
        self._pending = 0
        self.syncs += 1

    def close(self):
        if not self._f.closed:
            self._flush(force=True)
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
