"""``DurableStore`` — log-then-apply durability behind the GraphStore API.

Wraps any backend whose ``apply`` is deterministic (both shipped stores
are: fixed-shape padded batches, last-writer-wins). Every ``apply``
frames the EXACT batch into the write-ahead log before the in-memory
apply runs, so the on-disk stream replayed through a fresh store's
``apply`` reproduces the live state bit for bit. ``checkpoint()`` seals
the log: sync the WAL, write an (incremental when safe) epoch-consistent
checkpoint recording the last covered WAL seq, rotate to a fresh
segment, GC old chains and fully-covered segments.

Recovery (module function ``recover``) = newest valid checkpoint chain +
WAL suffix replay::

    store, report = recover(directory, lambda: make_store("local", ...))

Falls back checkpoint-by-checkpoint on corruption (dead newer
checkpoints from a diverged pre-crash future are truncated, exactly like
a log), and to a full WAL replay from empty when nothing is recoverable.
Everything else (reads, analytics, epochs, pins) delegates to the inner
store untouched — the wrapper is scheduling-transparent, so
``GraphQueryService`` takes a DurableStore like any other backend.
"""
from __future__ import annotations

import dataclasses
import pathlib
import shutil
import time
from typing import Callable, Optional

from repro.api.ir import ApplyResult, OpBatch
from repro.core.status import Reason
from repro.storage import checkpoint as ck
from repro.storage import wal as wl

__all__ = ["DurabilityConfig", "DurableStore", "recover"]


@dataclasses.dataclass
class DurabilityConfig:
    """Knobs of the durability subsystem (see README "Durability &
    crash recovery")."""

    group_commit: int = 32        # records per fsync (1 = sync every op)
    fsync: bool = True            # False: flush only (page-cache trust)
    incremental: bool = True      # delta checkpoints when row-safe
    checkpoint_every: Optional[int] = None   # auto-ckpt per N applies
    keep: int = 2                 # full checkpoint chains retained
    max_delta_frac: float = 0.5   # touched-block cap for deltas


class DurableStore:
    """GraphStore wrapper adding WAL + checkpoint durability."""

    def __init__(self, store, directory, *,
                 config: Optional[DurabilityConfig] = None,
                 injector=None, _start_seq: int = 0, **kw):
        self.inner = store
        self.directory = pathlib.Path(directory)
        self.config = config or DurabilityConfig(**kw)
        self.injector = injector
        self._wal_seq = _start_seq - 1     # last framed record seq
        self._applies_since_ckpt = 0
        self.wal_stats = dict(wal_records=0, wal_bytes=0, wal_syncs=0,
                              wal_ms=0.0, checkpoints=0, checkpoint_ms=0.0,
                              checkpoint_bytes=0, last_checkpoint_kind="")
        (self.directory / "wal").mkdir(parents=True, exist_ok=True)
        self._open_segment(_start_seq)

    def _open_segment(self, start_seq: int):
        self.wal = wl.WalWriter(
            self.directory / "wal" / f"wal_{start_seq:012d}.log",
            group_commit=self.config.group_commit,
            fsync=self.config.fsync, injector=self.injector)

    # ---- the durable write path ----
    def apply(self, batch: OpBatch) -> ApplyResult:
        if batch.kind not in self.supported_ops:
            # refuse BEFORE logging: an unsupported op must not poison
            # the replay stream (replay calls inner.apply verbatim)
            from repro.api.ir import UnsupportedOpError
            raise UnsupportedOpError(batch.kind, self.backend)
        if len(batch) == 0:
            return ApplyResult(0, 0)
        t0 = time.perf_counter()
        self._wal_seq += 1
        self.wal.append(self._wal_seq, batch)
        self.wal_stats["wal_ms"] = round(
            self.wal_stats["wal_ms"] +
            (time.perf_counter() - t0) * 1000.0, 3)
        res = self.inner.apply(batch)
        self._applies_since_ckpt += 1
        self.wal_stats["wal_records"] = self.wal.records_written
        self.wal_stats["wal_bytes"] = self.wal.bytes_written
        self.wal_stats["wal_syncs"] = self.wal.syncs
        ce = self.config.checkpoint_every
        if ce and self._applies_since_ckpt >= ce:
            self.checkpoint()
        return res

    def sync(self):
        """Force the group-commit boundary (durable ack point)."""
        self.wal.sync()
        self.wal_stats["wal_syncs"] = self.wal.syncs

    def checkpoint(self) -> dict:
        """Seal the log into a checkpoint: WAL sync, (incremental)
        checkpoint stamped with the covered WAL seq, segment rotation,
        GC of old chains and fully-covered segments."""
        t0 = time.perf_counter()
        self.sync()
        man = ck.save_graph_checkpoint(
            self.directory, self.inner,
            incremental=self.config.incremental,
            wal_seq=self._wal_seq, keep=self.config.keep,
            max_delta_frac=self.config.max_delta_frac)
        self.wal.close()
        self._open_segment(self._wal_seq + 1)
        self._prune_wal()
        self._applies_since_ckpt = 0
        self.wal_stats["checkpoints"] += 1
        self.wal_stats["checkpoint_ms"] = round(
            self.wal_stats["checkpoint_ms"] +
            (time.perf_counter() - t0) * 1000.0, 3)
        self.wal_stats["checkpoint_bytes"] = man["bytes"]
        self.wal_stats["last_checkpoint_kind"] = man["kind"]
        return man

    def _prune_wal(self):
        """Drop segments every retained checkpoint already covers: the
        OLDEST retained checkpoint's ``wal_seq`` bounds how far back any
        recovery can need to replay."""
        ids = ck.checkpoint_ids(self.directory)
        if not ids:
            return
        try:
            oldest = ck._read_manifest(self.directory, ids[0])
        except ck.CheckpointError:
            return
        horizon = oldest["wal_seq"]
        for p in wl.wal_segments(self.directory / "wal"):
            if p == self.wal.path:
                continue
            scan = wl.read_wal(p)
            if scan.tail is Reason.OK and scan.last_seq <= horizon:
                p.unlink()
            else:
                break      # segments are ordered; keep everything newer

    def close(self):
        self.wal.close()

    # ---- transparent delegation ----
    def __getattr__(self, name):
        return getattr(self.inner, name)

    @property
    def backend(self) -> str:
        return "durable+" + self.inner.backend

    @property
    def stats(self) -> dict:
        return {**self.inner.stats, **self.wal_stats}


def recover(directory, make_store: Callable[[], object], *,
            config: Optional[DurabilityConfig] = None, injector=None,
            **kw):
    """Rebuild a durable store from ``directory``: newest valid
    checkpoint chain (falling back on corruption) + deterministic replay
    of the WAL suffix. Returns ``(DurableStore, report)`` where the
    report records what recovery actually did::

        {"checkpoint": id|None, "checkpoint_kind": ..., "replayed": n,
         "wal_tail": Reason, "last_seq": int, "truncated_ckpts": [...]}
    """
    directory = pathlib.Path(directory)
    store = make_store()
    report = dict(checkpoint=None, checkpoint_kind=None, replayed=0,
                  wal_tail=Reason.OK, last_seq=-1, truncated_ckpts=[],
                  gap_at=None)
    after = -1
    hit = ck.latest_recoverable(directory)
    if hit is not None:
        _leaves, man = hit
        ck.restore_graph_checkpoint(directory, store, man["ckpt_id"])
        after = man["wal_seq"]
        report["checkpoint"] = man["ckpt_id"]
        report["checkpoint_kind"] = man["kind"]
        # newer checkpoints that failed validation are a dead (possibly
        # diverged) future — truncate them like a log suffix
        for i in ck.checkpoint_ids(directory):
            if i > man["ckpt_id"]:
                shutil.rmtree(ck._dir_of(directory, i),
                              ignore_errors=True)
                report["truncated_ckpts"].append(i)
    # seal the log: chop the first broken segment at its valid prefix
    # (so the torn garbage can never shadow post-recovery appends) and
    # retire segments past it — a broken tail means a seq gap, and a
    # deterministic replay must never jump one
    broken = False
    for p in wl.wal_segments(directory / "wal"):
        if broken:
            p.rename(p.with_name(p.name + ".dead"))
            continue
        scan = wl.read_wal(p)
        if scan.tail is not Reason.OK:
            with open(p, "r+b") as f:
                f.truncate(scan.valid_bytes)
            report["wal_tail"] = scan.tail
            broken = True
    scan = wl.read_wal_dir(directory / "wal", after_seq=after)
    expect = after + 1
    last = after
    for rec in scan.records:
        if rec.seq != expect:      # gap: records lost with a fallen-back
            report["gap_at"] = rec.seq   # checkpoint — stop, stay exact
            break
        store.apply(rec.batch)
        report["replayed"] += 1
        expect += 1
        last = rec.seq
    report["last_seq"] = last
    if report["gap_at"] is not None:
        # post-gap records are unreachable forever AND their seqs would
        # collide with the restarted log — retire those segments
        for p in wl.wal_segments(directory / "wal"):
            s = wl.read_wal(p)
            if s.records and s.records[-1].seq > last:
                p.rename(p.with_name(p.name + ".dead"))
    cfg = config or DurabilityConfig(**kw)
    dur = DurableStore(store, directory, config=cfg, injector=injector,
                       _start_seq=last + 1)
    return dur, report
