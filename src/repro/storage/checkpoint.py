"""Epoch-consistent graph checkpoints: full and incremental (block-row).

A checkpoint serializes ONE captured functional state — pool arrays,
vertex table, radix-sort index, MVCC scalars — plus the host counters a
restored process resumes with. Every array member carries a CRC32 of its
bytes in the manifest, so corruption is detected at restore, never
silently replayed over.

**Incremental checkpoints** reuse the PR-5 touched-row argument the
epoch-delta extractor is built on: between two states with an equal
``pool.defrags`` counter, block extents never move and all content
writes land inside the current extents of rows whose vertex-table
signature (``size``/``cap``/``start_block``/``deg``) changed, or inside
blocks holding entries stamped ``ts >= base_clock``. A delta checkpoint
therefore stores the small leaves in full (vertex table, sort index,
scalars — they are tiny) and only the TOUCHED BLOCK ROWS of the three
big pool arrays (``dst``/``weight``/``ts``), scattered over the base
chain at restore. Any defrag since the base (``defrags`` differs — the
manifest records the counter, satisfying the row-identity audit), any
overflow, or a touched fraction above ``max_delta_frac`` falls back to a
full checkpoint.

Atomicity: members are written into ``ckpt_<id>.tmp``, each fsynced,
the manifest LAST, then the directory is renamed into place and the
parent fsynced — a crash mid-checkpoint leaves a ``.tmp`` orphan that
recovery ignores.

Layout::

    <dir>/ckpt_00000007/manifest.json
                        sort__pools__0.npy ... pool__owner.npy
                        delta__blocks.npy  delta__pool__dst.npy ...
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import zlib
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.status import Reason

__all__ = ["CheckpointError", "save_graph_checkpoint",
           "restore_graph_checkpoint", "resolve_checkpoint",
           "checkpoint_ids", "latest_recoverable"]

FORMAT = "radixgraph-checkpoint"
VERSION = 1
_BIG = ("pool/dst", "pool/weight", "pool/ts")   # block-row delta members


class CheckpointError(RuntimeError):
    """Restore-side failure, typed by a ``core.status.Reason`` code."""

    def __init__(self, code: Reason, detail: str = ""):
        self.code = code
        super().__init__(f"{code}: {detail}" if detail else str(code))


# ---- pytree <-> named host leaves ----

def _key_str(k) -> str:
    for attr in ("name", "key", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def flatten_named(tree) -> Tuple[List[Tuple[str, np.ndarray]], object]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(_key_str(k) for k in path), leaf)
            for path, leaf in flat], treedef


def _fname(name: str) -> str:
    return name.replace("/", "__") + ".npy"


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


# ---- directory bookkeeping ----

def checkpoint_ids(directory) -> List[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return []
    ids = []
    for p in d.glob("ckpt_*"):
        if p.suffix == ".tmp" or not p.is_dir():
            continue
        try:
            ids.append(int(p.name.split("_", 1)[1]))
        except ValueError:
            continue
    return sorted(ids)


def _dir_of(directory, ckpt_id: int) -> pathlib.Path:
    return pathlib.Path(directory) / f"ckpt_{ckpt_id:08d}"


def _read_manifest(directory, ckpt_id: int) -> dict:
    p = _dir_of(directory, ckpt_id) / "manifest.json"
    try:
        man = json.loads(p.read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(Reason.CKPT_BAD_MANIFEST,
                              f"ckpt {ckpt_id}: {e}")
    if man.get("format") != FORMAT or man.get("version") != VERSION:
        raise CheckpointError(Reason.CKPT_BAD_MANIFEST,
                              f"ckpt {ckpt_id}: wrong format/version")
    return man


def _load_member(ckpt_dir: pathlib.Path, name: str, entry: dict
                 ) -> np.ndarray:
    path = ckpt_dir / entry["file"]
    try:
        arr = np.load(path)
    except Exception as e:   # missing file, chopped .npy header, ...
        raise CheckpointError(Reason.CKPT_BAD_CRC, f"{name}: {e}")
    if list(arr.shape) != entry["shape"] or str(arr.dtype) != entry["dtype"]:
        raise CheckpointError(Reason.CKPT_BAD_CRC,
                              f"{name}: shape/dtype mismatch")
    if _crc(arr) != entry["crc32"]:
        raise CheckpointError(Reason.CKPT_BAD_CRC, f"{name}: CRC mismatch")
    return arr


# ---- incremental block-row selection ----

def _pool3(a: np.ndarray, bs: Optional[int] = None) -> np.ndarray:
    """Normalize to a leading shard dim: (S, n_blocks[, bs])."""
    want = 2 if bs is None else 3
    return a if a.ndim == want else a[None]


def _touched_blocks(host: Dict[str, np.ndarray], base_small: dict,
                    base_clock: np.ndarray) -> np.ndarray:
    """Flat indices (into the shard-flattened block axis) of every block
    row whose content MAY differ from the base checkpoint — the
    epoch-delta touched-row argument applied to storage:

    * blocks holding an entry stamped at/after the base clock (fresh
      appends; per-vertex compaction preserves entry timestamps, so a
      moved window write still flags its new block);
    * the full current extent of every row whose vt signature changed
      (compaction relocates whole extents; the vacated blocks keep their
      old bytes and need no rewrite);
    * the full extent of rows allocated since the base.
    """
    ts = _pool3(host["pool/ts"], bs=0)
    owner = _pool3(host["pool/owner"])
    S, nb, bs = ts.shape
    size = _pool3(host["vt/size"])
    cap = _pool3(host["vt/cap"])
    start = _pool3(host["vt/start_block"])
    deg = _pool3(host["vt/deg"])
    nrows = np.asarray(host["vt/num_rows"]).reshape(-1)
    touched = np.zeros((S, nb), bool)
    for s in range(S):
        touched[s] = (ts[s] >= base_clock[s]).any(axis=1) & (owner[s] >= 0)
        bn = int(base_small["num_rows"][s])
        n_cap = size.shape[1]
        rowmask = np.zeros((n_cap,), bool)
        for cur, prev in ((size, "size"), (cap, "cap"),
                          (start, "start_block"), (deg, "deg")):
            rowmask[:bn] |= cur[s][:bn] != base_small[prev][s][:bn]
        rowmask[bn:int(nrows[s])] = True
        rowmask &= (cap[s] > 0) & (start[s] >= 0)
        rows = np.nonzero(rowmask)[0]
        if len(rows):
            starts = start[s][rows].astype(np.int64)
            counts = -(-cap[s][rows].astype(np.int64) // bs)
            reps = np.repeat(starts, counts)
            offs = np.arange(len(reps)) - np.repeat(
                np.cumsum(counts) - counts, counts)
            idx = reps + offs
            touched[s][idx[(idx >= 0) & (idx < nb)]] = True
    return np.nonzero(touched.reshape(-1))[0].astype(np.int64)


def _base_small(directory, base_man: dict) -> dict:
    """The base checkpoint's vt signature arrays (always stored in full,
    even in delta checkpoints) shaped (S, ...)."""
    d = _dir_of(directory, base_man["ckpt_id"])
    out = {}
    for name in ("size", "cap", "start_block", "deg", "num_rows"):
        key = f"vt/{name}"
        arr = _load_member(d, key, base_man["arrays"][key])
        out[name] = _pool3(arr) if name != "num_rows" \
            else np.asarray(arr).reshape(-1)
    return out


# ---- saving ----

def save_graph_checkpoint(directory, store, *, incremental: bool = True,
                          wal_seq: int = -1, keep: int = 2,
                          max_delta_frac: float = 0.5) -> dict:
    """Checkpoint ``store``'s live state under ``directory``; returns the
    manifest. ``incremental=True`` writes a block-row delta against the
    latest existing checkpoint whenever the row-identity guards hold.
    ``keep``: full chains retained by GC (older dirs are deleted after a
    successful save). ``wal_seq``: last WAL record covered — recovery
    replays strictly newer records."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    state, meta = store.durable_state()
    named, _ = flatten_named(state)
    host = {name: np.asarray(leaf) for name, leaf in named}
    S = getattr(store, "n_shards", 1)
    clock = np.asarray(host["pool/clock"]).reshape(-1).tolist()
    defrags = np.asarray(host["pool/defrags"]).reshape(-1).tolist()
    overflow = [int(np.asarray(host[k]).sum()) for k in
                ("sort/overflow", "vt/overflow", "pool/overflow")]

    ids = checkpoint_ids(directory)
    ckpt_id = (ids[-1] + 1) if ids else 0
    kind, base_id, blocks, why_full = "full", None, None, "no-base"
    if incremental and ids:
        try:
            base_man = _read_manifest(directory, ids[-1])
            if base_man["n_shards"] != S:
                why_full = "shard-mismatch"
            elif base_man["defrags"] != defrags:
                why_full = Reason.DEFRAG.value
            elif base_man["overflow"] != overflow:
                why_full = Reason.OVERFLOW.value
            else:
                blocks = _touched_blocks(
                    host, _base_small(directory, base_man),
                    np.asarray(base_man["clock"]))
                nb_total = int(np.prod(_pool3(host["pool/owner"]).shape))
                if len(blocks) > max_delta_frac * nb_total:
                    blocks, why_full = None, Reason.DELTA_TOO_LARGE.value
                else:
                    kind, base_id, why_full = "delta", ids[-1], ""
        except CheckpointError as e:
            blocks, why_full = None, str(e.code)

    tmp = directory / f"ckpt_{ckpt_id:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    def _write(name: str, arr: np.ndarray) -> dict:
        fn = _fname(name)
        with open(tmp / fn, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        return dict(file=fn, shape=list(arr.shape), dtype=str(arr.dtype),
                    crc32=_crc(arr))

    arrays, delta = {}, None
    if kind == "full":
        for name, _ in named:
            arrays[name] = _write(name, host[name])
        bytes_written = sum(host[n].nbytes for n in arrays)
    else:
        for name, _ in named:
            if name not in _BIG:
                arrays[name] = _write(name, host[name])
        d_arrays = {"delta/blocks": _write("delta/blocks", blocks)}
        bs = _pool3(host["pool/ts"], bs=0).shape[-1]
        for name in _BIG:
            rows = _pool3(host[name], bs=0).reshape(-1, bs)[blocks]
            d_arrays[f"delta/{name}"] = _write(f"delta/{name}", rows)
        delta = dict(n_blocks=int(len(blocks)),
                     arrays={f"delta/{n}": d_arrays[f"delta/{n}"]
                             for n in _BIG},
                     blocks=d_arrays["delta/blocks"])
        bytes_written = sum(host[n].nbytes for n in arrays) + \
            blocks.nbytes + sum(
                int(np.prod(e["shape"])) * np.dtype(e["dtype"]).itemsize
                for e in delta["arrays"].values())

    manifest = dict(
        format=FORMAT, version=VERSION, ckpt_id=ckpt_id, kind=kind,
        base=base_id, backend=getattr(store, "backend", "?"), n_shards=S,
        wal_seq=int(wal_seq), clock=clock, defrags=defrags,
        overflow=overflow, meta=meta, arrays=arrays, delta=delta,
        why_full=why_full, bytes=int(bytes_written))
    mpath = tmp / "manifest.json"
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    final = _dir_of(directory, ckpt_id)
    os.rename(tmp, final)
    dfd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
    _gc(directory, keep)
    return manifest


def _gc(directory, keep: int):
    """Retain the last ``keep`` FULL checkpoints and every delta chained
    on them; delete older dirs (a delta's base is always newer-or-equal
    to the previous full, so this never orphans a chain)."""
    if keep <= 0:
        return
    fulls = []
    for i in checkpoint_ids(directory):
        try:
            if _read_manifest(directory, i)["kind"] == "full":
                fulls.append(i)
        except CheckpointError:
            continue
    if len(fulls) <= keep:
        return
    cutoff = fulls[-keep]
    for i in checkpoint_ids(directory):
        if i < cutoff:
            shutil.rmtree(_dir_of(directory, i), ignore_errors=True)


# ---- loading ----

def resolve_checkpoint(directory, ckpt_id: int,
                       _depth: int = 0) -> Tuple[Dict[str, np.ndarray],
                                                 dict]:
    """Load checkpoint ``ckpt_id``, resolving its delta chain. Returns
    ``(named host leaves, manifest)``; raises ``CheckpointError`` on any
    CRC / chain / manifest failure."""
    if _depth > 64:
        raise CheckpointError(Reason.CKPT_BAD_CHAIN, "chain too deep")
    man = _read_manifest(directory, ckpt_id)
    d = _dir_of(directory, ckpt_id)
    leaves = {name: _load_member(d, name, entry)
              for name, entry in man["arrays"].items()}
    if man["kind"] == "delta":
        if man["base"] is None:
            raise CheckpointError(Reason.CKPT_BAD_CHAIN,
                                  f"ckpt {ckpt_id}: delta without base")
        try:
            base_leaves, _ = resolve_checkpoint(directory, man["base"],
                                                _depth + 1)
        except CheckpointError as e:
            raise CheckpointError(
                Reason.CKPT_BAD_CHAIN,
                f"ckpt {ckpt_id}: base {man['base']} unrecoverable "
                f"({e.code})") from e
        blocks = _load_member(d, "delta/blocks", man["delta"]["blocks"])
        for name in _BIG:
            rows = _load_member(d, f"delta/{name}",
                                man["delta"]["arrays"][f"delta/{name}"])
            big = base_leaves[name].copy()
            shape = big.shape
            bs = shape[-1]
            flat = big.reshape(-1, bs)
            flat[blocks] = rows
            leaves[name] = flat.reshape(shape)
    return leaves, man


def latest_recoverable(directory) -> Optional[Tuple[Dict[str, np.ndarray],
                                                    dict]]:
    """Newest checkpoint whose whole chain validates; None when nothing
    under ``directory`` is recoverable (corrupt members are skipped, not
    fatal — recovery falls back to older checkpoints, then to a bare WAL
    replay)."""
    for i in reversed(checkpoint_ids(directory)):
        try:
            return resolve_checkpoint(directory, i)
        except CheckpointError:
            continue
    return None


def restore_graph_checkpoint(directory, store,
                             ckpt_id: Optional[int] = None) -> dict:
    """Install a checkpointed state into ``store`` (same spec); returns
    the manifest restored from. ``ckpt_id=None`` picks the newest fully
    valid chain."""
    if ckpt_id is not None:
        leaves, man = resolve_checkpoint(directory, ckpt_id)
    else:
        hit = latest_recoverable(directory)
        if hit is None:
            raise CheckpointError(Reason.CKPT_MISSING,
                                  f"no recoverable checkpoint in "
                                  f"{directory}")
        leaves, man = hit
    template, _ = store.durable_state()
    named, treedef = flatten_named(template)
    vals = []
    for name, leaf in named:
        if name not in leaves:
            raise CheckpointError(Reason.CKPT_BAD_MANIFEST,
                                  f"member {name} missing")
        arr = leaves[name]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise CheckpointError(
                Reason.CKPT_BAD_MANIFEST,
                f"member {name}: checkpoint shape {arr.shape} vs store "
                f"{np.shape(leaf)} — mismatched store spec")
        vals.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, vals)
    store.load_durable_state(state, man.get("meta", {}))
    return man
