"""Crash-recovery smoke: kill a real ingest subprocess, recover, assert
bit-exact parity against an uninterrupted control run.

Protocol (one command, used by CI and the slow test):

1. the parent builds a deterministic edge stream (seeded) and picks a
   kill batch;
2. a CHILD process ingests the stream through a ``DurableStore``
   (group-commit WAL + periodic incremental checkpoints) and SIGKILLs
   itself right after applying the kill batch — unsynced group-commit
   tail and all, exactly like a power cut;
3. the parent recovers from the directory, derives how many batches
   survived (the recovery report's ``last_seq``), replays the control
   store to that same prefix, and asserts the epoch CSR snapshot,
   ``num_edges`` and a PageRank run are bit-exact;
4. the parent then finishes the stream on the RECOVERED store and
   asserts final parity with the full control run — restart + replay
   loses nothing but the unsynced tail.

    PYTHONPATH=src python -m repro.storage.crash_smoke --seed 7

Exit code 0 = every assertion held. ``--json`` prints the summary
record (the CI step uploads it next to the bench artifacts).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import signal
import subprocess
import sys
import tempfile

import numpy as np

CAPS = dict(n_max=4096, expected_n=2048, pool_blocks=8192, block_size=16,
            k_max=128, dmax=1024, batch=512)


def _stream(seed: int, n_ops: int, batch: int):
    """Deterministic mixed insert/delete batches (shared parent/child)."""
    from repro.api import OpBatch
    rng = np.random.default_rng(seed)
    ids = rng.choice(2 ** 24, CAPS["n_max"] // 2,
                     replace=False).astype(np.uint64)
    out = []
    for lo in range(0, n_ops, batch):
        n = min(batch, n_ops - lo)
        w = rng.uniform(0.5, 2.0, n).astype(np.float32)
        w[rng.random(n) < 0.05] = 0.0        # tombstones ride along
        out.append(OpBatch.edges(rng.choice(ids, n), rng.choice(ids, n),
                                 w))
    return out


def _mk_store():
    from repro.api import make_store
    return make_store("local", **CAPS)


def _child(args) -> int:
    from repro.storage import DurableStore
    batches = _stream(args.seed, args.ops, args.batch)
    store = DurableStore(_mk_store(), args.dir,
                         group_commit=args.group_commit,
                         checkpoint_every=max(2, len(batches) // 3))
    for i, b in enumerate(batches):
        store.apply(b)
        if i == args.kill_batch:
            os.kill(os.getpid(), signal.SIGKILL)   # no flush, no goodbye
    return 0


def _snapshot_sig(store):
    import jax
    from repro.api import AnalyticsOp, ReadOp
    snap = store.read(ReadOp("snapshot"))
    leaves = [np.asarray(x) for x in jax.tree.leaves(snap)]
    pr = store.analytics(AnalyticsOp("pagerank", {"iters": 10}))
    return dict(num_edges=store.read(ReadOp("num_edges")), leaves=leaves,
                pagerank=pr)


def _assert_sig_equal(a: dict, b: dict, where: str):
    assert a["num_edges"] == b["num_edges"], \
        f"{where}: num_edges {a['num_edges']} != {b['num_edges']}"
    for i, (x, y) in enumerate(zip(a["leaves"], b["leaves"])):
        assert np.array_equal(x, y), f"{where}: snapshot leaf {i} differs"
    assert a["pagerank"] == b["pagerank"], f"{where}: pagerank differs"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ops", type=int, default=8192)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--group-commit", type=int, default=8)
    ap.add_argument("--dir", default=None)
    ap.add_argument("--kill-batch", type=int, default=None)
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--_child", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args._child:
        return _child(args)

    from repro.storage import recover
    rng = np.random.default_rng(args.seed + 1000)
    n_batches = (args.ops + args.batch - 1) // args.batch
    kill = args.kill_batch if args.kill_batch is not None else int(
        rng.integers(n_batches // 4, max(n_batches // 4 + 1,
                                         3 * n_batches // 4)))
    workdir = args.dir or tempfile.mkdtemp(prefix="crash_smoke_")
    pathlib.Path(workdir).mkdir(parents=True, exist_ok=True)

    cmd = [sys.executable, "-m", "repro.storage.crash_smoke", "--_child",
           "--seed", str(args.seed), "--ops", str(args.ops),
           "--batch", str(args.batch),
           "--group-commit", str(args.group_commit),
           "--dir", workdir, "--kill-batch", str(kill)]
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1200)
    assert proc.returncode == -signal.SIGKILL, \
        f"child should die by SIGKILL, got rc={proc.returncode}\n" \
        f"{proc.stderr[-2000:]}"

    store, report = recover(workdir, _mk_store)
    batches = _stream(args.seed, args.ops, args.batch)
    survived = report["last_seq"] + 1          # seqs are batch-aligned
    assert 0 <= survived <= kill + 1, (survived, kill)

    ctrl = _mk_store()
    for b in batches[:survived]:
        ctrl.apply(b)
    _assert_sig_equal(_snapshot_sig(ctrl), _snapshot_sig(store),
                      "recovered prefix")

    # restart semantics: finish the stream on the recovered store
    for b in batches[survived:]:
        store.apply(b)
    store.checkpoint()
    store.close()
    for b in batches[survived:]:
        ctrl.apply(b)
    _assert_sig_equal(_snapshot_sig(ctrl), _snapshot_sig(store),
                      "resumed stream")

    rec = dict(status="ok", seed=args.seed, ops=args.ops,
               batches=n_batches, kill_batch=kill,
               survived_batches=survived,
               lost_tail_batches=kill + 1 - survived,
               checkpoint=report["checkpoint"],
               checkpoint_kind=report["checkpoint_kind"],
               replayed=report["replayed"],
               wal_tail=str(report["wal_tail"]))
    if args.json:
        print(json.dumps(rec, indent=1))
    else:
        print(f"[OK] crash smoke: killed at batch {kill}/{n_batches}, "
              f"{survived} batches durable (ckpt {report['checkpoint']} "
              f"{report['checkpoint_kind']} + {report['replayed']} WAL "
              f"records replayed, tail={report['wal_tail']}), prefix and "
              f"resumed-stream parity bit-exact")
    return 0


if __name__ == "__main__":
    sys.exit(main())
