"""``repro.storage`` — durability for graph stores.

The paper's snapshot-log split, taken to disk: epoch-consistent
checkpoints (full + incremental block-row deltas, per-array CRCs) are
the snapshots, an fsync-batched write-ahead log of applied ``OpBatch``es
is the log, and recovery is "load the newest valid chain, replay the WAL
suffix through the deterministic ``GraphStore.apply``".

    from repro.storage import DurableStore, recover

    store = DurableStore(make_store("local", ...), "/data/graph",
                         group_commit=32, checkpoint_every=256)
    store.apply(OpBatch.edges(src, dst, w))     # logged before applied
    store.checkpoint()                          # seal + rotate + GC

    store, report = recover("/data/graph", lambda: make_store("local", ...))

``faultfs`` holds the fault-injection harness the recovery tests drive
(torn WAL tails, flipped bytes, torn checkpoint directories).
"""
from .checkpoint import (CheckpointError, checkpoint_ids,
                         latest_recoverable, resolve_checkpoint,
                         restore_graph_checkpoint, save_graph_checkpoint)
from .durable import DurabilityConfig, DurableStore, recover
from .faultfs import FaultInjector, InjectedCrash
from .wal import (WalRecord, WalScan, WalWriter, decode_batch,
                  encode_batch, encode_record, read_wal, read_wal_dir,
                  wal_segments)

__all__ = [
    "CheckpointError", "checkpoint_ids", "latest_recoverable",
    "resolve_checkpoint", "restore_graph_checkpoint",
    "save_graph_checkpoint",
    "DurabilityConfig", "DurableStore", "recover",
    "FaultInjector", "InjectedCrash",
    "WalRecord", "WalScan", "WalWriter", "decode_batch", "encode_batch",
    "encode_record", "read_wal", "read_wal_dir", "wal_segments",
]
