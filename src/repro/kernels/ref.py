"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: kernels must match them bit-exactly
(integer outputs) / allclose (float outputs) across the test sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compact_rows_ref", "defrag_rows_ref", "sort_lookup_ref",
           "frontier_ref", "append_ref"]


def append_ref(dst: jnp.ndarray, w: jnp.ndarray, ts: jnp.ndarray,
               wblk: jnp.ndarray, wlane: jnp.ndarray, wval: jnp.ndarray,
               wd: jnp.ndarray, ww: jnp.ndarray, wts: jnp.ndarray,
               pstart: jnp.ndarray, psize: jnp.ndarray, pv: jnp.ndarray):
    """Fused-append oracle: pool scatter + pre-append last-writer probe.

    Pools are (NB, BS) = (dst offsets, weights, timestamps). Per op j (B,):
    write (wd, ww, wts)[j] at pool[wblk[j], wlane[j]] when ``wval[j]``. Per
    probe q (B,): scan the FULL extent [pstart[q], ·) of the owning vertex
    (occupied prefix ``psize`` entries) for destination ``pv[q]`` and report
    whether the highest-timestamp match carries a non-NULL weight —
    ``was_live`` of the (owner, pv) pair BEFORE this batch's appends land
    (appends only ever claim slots at/after the pre-batch size, so probe and
    write order commute). ``pv < 0`` disables a probe row.

    Returns (dst', w', ts', was_live[B] bool).
    """
    NB, BS = dst.shape
    N = NB * BS
    e = jnp.arange(N, dtype=jnp.int32)
    blk, lane = e // BS, e % BS
    pos = (blk[None, :] - pstart[:, None]) * BS + lane[None, :]
    belongs = (pstart[:, None] >= 0) & (pos >= 0) & (pos < psize[:, None])
    match = belongs & (dst.reshape(-1)[None, :] == pv[:, None]) & \
        (pv[:, None] >= 0)
    tm = jnp.where(match, ts.reshape(-1)[None, :], 0)
    best = jnp.argmax(tm, axis=1)
    was_live = (jnp.max(tm, axis=1) > 0) & (w.reshape(-1)[best] != 0)

    tb = jnp.where(wval, wblk, NB)
    nd = dst.at[tb, wlane].set(wd, mode="drop")
    nw = w.at[tb, wlane].set(ww, mode="drop")
    nt = ts.at[tb, wlane].set(wts, mode="drop")
    return nd, nw, nt, was_live


def compact_rows_ref(dst: jnp.ndarray, w: jnp.ndarray, ts: jnp.ndarray,
                     size: jnp.ndarray, read_ts: jnp.ndarray | None = None):
    """Log compaction (paper Algorithm 2) on a batch of edge arrays.

    Inputs are (K, D): destination offsets (-1 = empty slot), weights
    (0 = NULL/tombstone), timestamps; ``size`` (K,) is the occupied prefix.
    Semantics = the paper's reverse scan with a duplicate-checker bitmap:
    for each destination the entry at the highest occupied position wins;
    tombstones drop the edge. Survivors are emitted in reverse-scan order
    (descending position). ``read_ts`` optionally restricts to entries with
    ts <= read_ts (MVCC time-travel reads).

    Returns (dst', w', ts', count) with compacted rows front-packed and empty
    slots set to (-1, 0, 0).
    """
    K, D = dst.shape
    pos = jnp.broadcast_to(jnp.arange(D, dtype=jnp.int32), (K, D))
    valid = (pos < size[:, None]) & (dst >= 0)
    if read_ts is not None:
        valid = valid & (ts <= jnp.asarray(read_ts, ts.dtype))

    BIGD = jnp.int32(2 ** 30)
    dkey = jnp.where(valid, dst, BIGD)  # invalid entries sort to the end
    # lexicographic per-row sort by (dst asc, pos asc):
    o1 = jnp.argsort(pos, axis=-1, stable=True)  # identity, keeps shape logic clear
    o2 = jnp.argsort(jnp.take_along_axis(dkey, o1, -1), axis=-1, stable=True)
    order = jnp.take_along_axis(o1, o2, -1)
    ds = jnp.take_along_axis(dkey, order, -1)
    ps = jnp.take_along_axis(pos, order, -1)
    ws = jnp.take_along_axis(w, order, -1)
    tss = jnp.take_along_axis(ts, order, -1)

    nxt = jnp.concatenate([ds[:, 1:], jnp.full((K, 1), -2, ds.dtype)], axis=-1)
    is_last = (ds != nxt) & (ds < BIGD)           # max position per dst
    keep = is_last & (ws != 0)

    # emit survivors by descending original position (reverse-scan order)
    emit_key = jnp.where(keep, D - ps, BIGD)
    o3 = jnp.argsort(emit_key, axis=-1, stable=True)
    dso = jnp.take_along_axis(jnp.where(keep, ds, -1), o3, -1)
    wso = jnp.take_along_axis(jnp.where(keep, ws, 0.0), o3, -1)
    tso = jnp.take_along_axis(jnp.where(keep, tss, 0), o3, -1)
    count = jnp.sum(keep.astype(jnp.int32), axis=-1)
    return dso, wso, tso, count


def defrag_rows_ref(dst: jnp.ndarray, w: jnp.ndarray, ts: jnp.ndarray,
                    size: jnp.ndarray, keep_all: bool = False):
    """Defrag row compactor: the streaming rebuild's per-vertex pass.

    Inputs are (K, D) edge-array gathers like ``compact_rows_ref`` —
    destination offsets (-1 = empty), weights (0 = NULL tombstone),
    timestamps — with ``size`` (K,) the occupied prefix. Rows must be
    position-ordered with the pool's append invariant: per destination,
    later positions carry later timestamps (holds for every extent the
    fast path or a previous defrag built). Semantics match the global
    rebuild's per-owner slice:

    * last-writer-wins per destination (the highest-position entry — by
      the invariant, also the newest timestamp), tombstones dropped;
    * survivors emitted sorted by destination ASCENDING (the defrag's
      CSR discipline, unlike ``compact_rows_ref``'s reverse-scan order);
    * ``keep_all=True`` (the 'grow' policy) keeps every occupied entry —
      duplicates and tombstones included — sorted by (dst, position).

    Returns (dst', w', ts', count, live): ``count`` entries front-packed
    per row (empty slots (-1, 0, 0)); ``live`` is the live-pair count
    (last entry per destination carries a non-NULL weight) regardless of
    ``keep_all`` — the defrag's exact ``live_m`` resync contribution.
    """
    K, D = dst.shape
    pos = jnp.broadcast_to(jnp.arange(D, dtype=jnp.int32), (K, D))
    valid = (pos < size[:, None]) & (dst >= 0)
    BIGD = jnp.int32(2 ** 30)
    dkey = jnp.where(valid, dst, BIGD)
    order = jnp.argsort(dkey, axis=-1, stable=True)  # (dst asc, pos asc)
    ds = jnp.take_along_axis(dkey, order, -1)
    ws = jnp.take_along_axis(w, order, -1)
    tss = jnp.take_along_axis(ts, order, -1)
    nxt = jnp.concatenate([ds[:, 1:], jnp.full((K, 1), -2, ds.dtype)],
                          axis=-1)
    is_last = (ds != nxt) & (ds < BIGD)
    live = jnp.sum((is_last & (ws != 0)).astype(jnp.int32), axis=-1)
    keep = (ds < BIGD) if keep_all else (is_last & (ws != 0))
    # survivors are already in emission order: front-pack with one scatter
    kpos = jnp.cumsum(keep.astype(jnp.int32), axis=-1) - 1
    tgt = jnp.where(keep, kpos, D)
    rows = jnp.broadcast_to(jnp.arange(K, dtype=jnp.int32)[:, None], (K, D))
    dso = jnp.full((K, D), -1, dst.dtype).at[rows, tgt].set(ds, mode="drop")
    wso = jnp.zeros((K, D), w.dtype).at[rows, tgt].set(ws, mode="drop")
    tso = jnp.zeros((K, D), ts.dtype).at[rows, tgt].set(tss, mode="drop")
    count = jnp.sum(keep.astype(jnp.int32), axis=-1)
    return dso, wso, tso, count, live


def sort_lookup_ref(pools, counts, keys: jnp.ndarray, *, fanout_bits,
                    bit_offsets) -> jnp.ndarray:
    """SORT descent oracle: (B, 2) uint32 keys -> int32 offsets (-1 absent).

    ``pools`` is the tuple of per-layer flat node pools; fanout_bits /
    bit_offsets are the static layer structure.
    """
    from repro.core.keys import extract_bits

    B = keys.shape[0]
    node = jnp.zeros((B,), jnp.int32)
    valid = jnp.ones((B,), bool)
    for i, (a, boff) in enumerate(zip(fanout_bits, bit_offsets)):
        idx = extract_bits(keys, boff, a)
        slot = node * (1 << a) + idx
        child = pools[i][jnp.clip(slot, 0, pools[i].shape[0] - 1)]
        child = jnp.where(valid, child, -1)
        valid = child >= 0
        node = jnp.maximum(child, 0)
    return jnp.where(valid, node, -1)


def frontier_ref(owner: jnp.ndarray, dst: jnp.ndarray, valid: jnp.ndarray,
                 frontier_bits: jnp.ndarray, visited_bits: jnp.ndarray):
    """BFS frontier expansion oracle.

    owner: (NB,) vertex offset per pool block (-1 unused)
    dst:   (NB, BS) destination offsets
    valid: (NB, BS) liveness mask of each entry
    frontier_bits / visited_bits: (W,) uint32 bitmaps over vertex offsets.

    Returns next_bits (W,) uint32: destinations of live edges whose owner is
    in the frontier, minus already-visited vertices.
    """
    W = frontier_bits.shape[0]
    own_ok = (owner >= 0)
    fw = frontier_bits[jnp.clip(owner, 0, W * 32 - 1) // 32]
    fbit = (fw >> (jnp.clip(owner, 0, W * 32 - 1) % 32).astype(jnp.uint32)) & 1
    on_frontier = own_ok & (fbit == 1)
    m = valid & on_frontier[:, None] & (dst >= 0)
    d = jnp.where(m, dst, 0)
    word = d // 32
    bit = jnp.left_shift(jnp.uint32(1), (d % 32).astype(jnp.uint32))
    # scatter-OR: two entries may target different bits of one word, so a
    # plain scatter-max of bit values is lossy. Build the OR per bit plane
    # (32 scatter-max passes — fine for an oracle).
    flat_word = word.reshape(-1)
    flat_bit = jnp.where(m.reshape(-1), bit.reshape(-1), jnp.uint32(0))
    next_bits = jnp.zeros((W,), jnp.uint32)
    for b in range(32):
        has = (flat_bit >> jnp.uint32(b)) & jnp.uint32(1)
        hit = jnp.zeros((W,), jnp.uint32).at[flat_word].max(has)
        next_bits = next_bits | (hit << jnp.uint32(b))
    next_bits = next_bits & ~visited_bits
    return next_bits
