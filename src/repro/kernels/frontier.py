"""Pallas TPU kernel: BFS frontier expansion over the flat edge pool.

This is the edge-chain payoff (paper §3.3 Fig. 6): traversal chases vertex
*offsets* straight out of edge blocks — no vertex-index lookups. One grid
step processes a tile of pool blocks; the frontier bitmap and the
accumulating next-frontier bitmap both live in VMEM (the same segmented
bitmap the duplicate checker uses).

The output bitmap block maps to the *same* window every grid step — TPU
grids are sequential, so read-modify-write accumulation across steps is
legal (revisiting). Validated in interpret mode vs ``ref.frontier_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["frontier_pallas"]


def _kernel(owner_ref, dst_ref, valid_ref, fbits_ref, out_ref):
    TB, BS = dst_ref.shape

    @pl.when(pl.program_id(0) == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    def per_block(b, _):
        o = owner_ref[b]
        fw = fbits_ref[jnp.right_shift(jnp.maximum(o, 0), 5)]
        on = (o >= 0) & (((fw >> (jnp.maximum(o, 0) & 31).astype(jnp.uint32))
                          & 1) == 1)

        def per_lane(j, _):
            d = dst_ref[b, j]
            ok = on & valid_ref[b, j] & (d >= 0)

            @pl.when(ok)
            def _():
                w = jnp.right_shift(d, 5)
                bit = jnp.uint32(1) << (d & 31).astype(jnp.uint32)
                out_ref[w] = out_ref[w] | bit

            return 0

        jax.lax.fori_loop(0, BS, per_lane, 0)
        return 0

    jax.lax.fori_loop(0, TB, per_block, 0)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def frontier_pallas(owner, dst, valid, frontier_bits, visited_bits,
                    tile: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    NB, BS = dst.shape
    tile = min(tile, NB)
    assert NB % tile == 0, "pad the pool to a multiple of the block tile"
    W = frontier_bits.shape[0]
    grid = (NB // tile,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile, BS), lambda i: (i, 0)),
            pl.BlockSpec((tile, BS), lambda i: (i, 0)),
            pl.BlockSpec((W,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((W,), lambda i: (0,)),  # revisited every step
        out_shape=jax.ShapeDtypeStruct((W,), jnp.uint32),
        interpret=interpret,
    )(owner, dst, valid, frontier_bits)
    return out & ~visited_bits
