"""Pallas TPU kernel: fused edge-pool append (ingest fast path).

One grid step owns one TOUCHED tile of pool block-rows resident in VMEM —
the tile list is a scalar-prefetch argument (``PrefetchScalarGridSpec``),
computed per batch from the owner extents the batch's probes span plus the
block rows its slots land in, so the kernel never scans tiles no op can
reach. The grid length stays static (one step per pool tile) but steps past
``n_touched`` revisit the last touched tile and skip all work: with the
revisiting-window pipeline that is zero DMA and zero compute, so a batch's
cost is O(touched_tiles x B) instead of the old O(pool_tiles x B) full-pool
scan. Each visited step makes a single pass that fuses the three stages the
XLA path runs separately:

1. **probe** — for every distinct (owner, dst) pair of the batch, scan the
   owner's extent rows that fall inside this tile for the pair's newest
   entry (last-writer-wins by timestamp), accumulating (best_ts, best_w) in
   VMEM scratch across tiles. Because appends only claim slots at/after the
   owner's pre-batch size, probing bounded by ``psize`` commutes with the
   writes of the same tile;
2. **slot scatter** — land every op's (dst, weight, ts) at its claimed slot
   (block, lane) when the slot falls inside the tile — the batched analogue
   of the paper's ``fetch_add`` log append, one pass for all three payloads
   instead of three XLA scatters;
3. **liveness finalize** — after the last grid step, emit ``was_live`` per
   pair ((best_ts > 0) & (best_w != 0)), the exact pre-batch pair liveness
   that drives the O(1) ``live_m`` counter with NO bounded-window blind spot.

The pool payloads alias their outputs (``input_output_aliases``), so tiles
the batch never touches keep their contents without ever moving through
VMEM. TPU grids are sequential, so the scratch accumulators and the
revisited ``was_live`` output window are legal (same pattern as
kernels/frontier.py). Validated in interpret mode (CPU container) against
``ref.append_ref``, which itself matches the ``_scatter_entries`` +
dense-probe semantics under the probe/write commutation invariant above.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["append_pallas", "append_tile_rows"]


def append_tile_rows(nb: int, tile: int = 128) -> int:
    """The tile height (pool block rows per grid step) the append kernel
    uses for an ``nb``-row pool — shared with the host-side touched-tile
    computation so the prefetched tile indices mean the same thing."""
    tile = min(tile, nb)
    while nb % tile:
        tile //= 2
    return tile


def _kernel(tiles, n_touched, dp, wp, tp, wblk, wlane, wval, wd, ww, wts,
            pstart, psize, pv, od, ow, ot, owas, best_ts, best_w):
    T, BS = dp.shape
    B = wblk.shape[0]
    pid = pl.program_id(0)
    t0 = tiles[pid] * T

    @pl.when(pid == 0)
    def _():
        best_ts[...] = jnp.zeros_like(best_ts)
        best_w[...] = jnp.zeros_like(best_w)
        owas[...] = jnp.zeros_like(owas)

    # pid 0 always visits (an identity copy of its tile when the batch
    # touches nothing): the output VMEM windows must be initialized before
    # the pipeline flushes them over the aliased pool buffer
    @pl.when((pid < n_touched[0]) | (pid == 0))
    def _visit():
        # ---- probe pass (pre-append tile contents) ----
        def probe(q, _):
            sb = pstart[q]
            sz = psize[q]
            v = pv[q]
            nblk = (sz + BS - 1) // BS
            lo = jnp.maximum(sb, t0)
            hi = jnp.minimum(sb + nblk, t0 + T)
            ok_q = (sb >= 0) & (v >= 0)

            def row(r, _):
                local = r - t0

                def lane(j, _):
                    pos = (r - sb) * BS + j
                    d = dp[local, j]
                    t = tp[local, j]
                    hit = ok_q & (pos < sz) & (d == v) & (t > best_ts[q])

                    @pl.when(hit)
                    def _():
                        best_ts[q] = t
                        best_w[q] = wp[local, j]

                    return 0

                jax.lax.fori_loop(0, BS, lane, 0)
                return 0

            jax.lax.fori_loop(lo, jnp.maximum(lo, hi), row, 0)
            return 0

        jax.lax.fori_loop(0, B, probe, 0)

        # ---- append pass: copy tile, land this tile's slots ----
        od[...] = dp[...]
        ow[...] = wp[...]
        ot[...] = tp[...]

        def wr(j, _):
            blk = wblk[j]

            @pl.when((wval[j] != 0) & (blk >= t0) & (blk < t0 + T))
            def _():
                b = blk - t0
                ln = wlane[j]
                od[pl.ds(b, 1), pl.ds(ln, 1)] = wd[j][None, None]
                ow[pl.ds(b, 1), pl.ds(ln, 1)] = ww[j][None, None]
                ot[pl.ds(b, 1), pl.ds(ln, 1)] = wts[j][None, None]

            return 0

        jax.lax.fori_loop(0, B, wr, 0)

    @pl.when(pid == pl.num_programs(0) - 1)
    def _():
        owas[...] = ((best_ts[...] > 0) &
                     (best_w[...] != 0)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def append_pallas(dst, w, ts, wblk, wlane, wval, wd, ww, wts,
                  pstart, psize, pv, tiles=None, n_touched=None,
                  tile: int = 128, interpret: bool | None = None):
    """Drop-in for ``ref.append_ref`` (same outputs). ``tiles`` is the
    prefetched visit order — touched pool tiles first (ascending), then the
    last touched tile repeated out to the grid length; ``n_touched`` is its
    valid prefix. Omitting both falls back to visiting every tile."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    NB, BS = dst.shape
    tile = append_tile_rows(NB, tile)
    B = wblk.shape[0]
    n_tiles = NB // tile
    if tiles is None:
        tiles = jnp.arange(n_tiles, dtype=jnp.int32)
        n_touched = jnp.asarray(n_tiles, jnp.int32)
    ptile = pl.BlockSpec((tile, BS), lambda i, tl, nt: (tl[i], 0))
    ops = pl.BlockSpec((B,), lambda i, tl, nt: (0,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles,),
        in_specs=[ptile, ptile, ptile] + [ops] * 9,
        out_specs=[ptile, ptile, ptile, ops],
        scratch_shapes=[pltpu.VMEM((B,), jnp.int32),
                        pltpu.VMEM((B,), jnp.float32)],
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((NB, BS), dst.dtype),
            jax.ShapeDtypeStruct((NB, BS), w.dtype),
            jax.ShapeDtypeStruct((NB, BS), ts.dtype),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        # pool payloads alias their outputs: untouched tiles keep their
        # contents without a copy (operand indices count the two
        # scalar-prefetch arguments)
        input_output_aliases={2: 0, 3: 1, 4: 2},
        interpret=interpret,
    )(tiles, jnp.reshape(jnp.asarray(n_touched, jnp.int32), (1,)),
      dst, w, ts, wblk, wlane, wval.astype(jnp.int32), wd, ww, wts,
      pstart, psize, pv)
    nd, nw, nt, was = out
    return nd, nw, nt, was == 1
