"""Pallas TPU kernel: fused edge-pool append (ingest fast path).

One grid step owns one tile of pool block-rows resident in VMEM and makes a
single pass that fuses the three stages the XLA path runs separately:

1. **probe** — for every distinct (owner, dst) pair of the batch, scan the
   owner's extent rows that fall inside this tile for the pair's newest
   entry (last-writer-wins by timestamp), accumulating (best_ts, best_w) in
   VMEM scratch across tiles. Because appends only claim slots at/after the
   owner's pre-batch size, probing bounded by ``psize`` commutes with the
   writes of the same tile;
2. **slot scatter** — land every op's (dst, weight, ts) at its claimed slot
   (block, lane) when the slot falls inside the tile — the batched analogue
   of the paper's ``fetch_add`` log append, one pass for all three payloads
   instead of three XLA scatters;
3. **liveness finalize** — after the last tile, emit ``was_live`` per pair
   ((best_ts > 0) & (best_w != 0)), the exact pre-batch pair liveness that
   drives the O(1) ``live_m`` counter with NO bounded-window blind spot.

TPU grids are sequential, so the scratch accumulators and the revisited
``was_live`` output window are legal (same pattern as kernels/frontier.py).
Validated in interpret mode (CPU container) against ``ref.append_ref``,
which itself matches the ``_scatter_entries`` + dense-probe semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["append_pallas"]


def _kernel(dp, wp, tp, wblk, wlane, wval, wd, ww, wts, pstart, psize, pv,
            od, ow, ot, owas, best_ts, best_w):
    T, BS = dp.shape
    B = wblk.shape[0]
    pid = pl.program_id(0)
    t0 = pid * T

    @pl.when(pid == 0)
    def _():
        best_ts[...] = jnp.zeros_like(best_ts)
        best_w[...] = jnp.zeros_like(best_w)
        owas[...] = jnp.zeros_like(owas)

    # ---- probe pass (pre-append tile contents) ----
    def probe(q, _):
        sb = pstart[q]
        sz = psize[q]
        v = pv[q]
        nblk = (sz + BS - 1) // BS
        lo = jnp.maximum(sb, t0)
        hi = jnp.minimum(sb + nblk, t0 + T)
        ok_q = (sb >= 0) & (v >= 0)

        def row(r, _):
            local = r - t0

            def lane(j, _):
                pos = (r - sb) * BS + j
                d = dp[local, j]
                t = tp[local, j]
                hit = ok_q & (pos < sz) & (d == v) & (t > best_ts[q])

                @pl.when(hit)
                def _():
                    best_ts[q] = t
                    best_w[q] = wp[local, j]

                return 0

            jax.lax.fori_loop(0, BS, lane, 0)
            return 0

        jax.lax.fori_loop(lo, jnp.maximum(lo, hi), row, 0)
        return 0

    jax.lax.fori_loop(0, B, probe, 0)

    # ---- append pass: copy tile, land this tile's slots ----
    od[...] = dp[...]
    ow[...] = wp[...]
    ot[...] = tp[...]

    def wr(j, _):
        blk = wblk[j]

        @pl.when((wval[j] != 0) & (blk >= t0) & (blk < t0 + T))
        def _():
            b = blk - t0
            ln = wlane[j]
            od[pl.ds(b, 1), pl.ds(ln, 1)] = wd[j][None, None]
            ow[pl.ds(b, 1), pl.ds(ln, 1)] = ww[j][None, None]
            ot[pl.ds(b, 1), pl.ds(ln, 1)] = wts[j][None, None]

        return 0

    jax.lax.fori_loop(0, B, wr, 0)

    @pl.when(pid == pl.num_programs(0) - 1)
    def _():
        owas[...] = ((best_ts[...] > 0) &
                     (best_w[...] != 0)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def append_pallas(dst, w, ts, wblk, wlane, wval, wd, ww, wts,
                  pstart, psize, pv, tile: int = 128,
                  interpret: bool | None = None):
    """Drop-in for ``ref.append_ref`` (same outputs)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    NB, BS = dst.shape
    tile = min(tile, NB)
    while NB % tile:
        tile //= 2
    B = wblk.shape[0]
    grid = (NB // tile,)
    ptile = pl.BlockSpec((tile, BS), lambda i: (i, 0))
    ops = pl.BlockSpec((B,), lambda i: (0,))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[ptile, ptile, ptile] + [ops] * 9,
        out_specs=[ptile, ptile, ptile, ops],
        out_shape=[
            jax.ShapeDtypeStruct((NB, BS), dst.dtype),
            jax.ShapeDtypeStruct((NB, BS), w.dtype),
            jax.ShapeDtypeStruct((NB, BS), ts.dtype),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((B,), jnp.int32),
                        pltpu.VMEM((B,), jnp.float32)],
        interpret=interpret,
    )(dst, w, ts, wblk, wlane, wval.astype(jnp.int32), wd, ww, wts,
      pstart, psize, pv)
    nd, nw, nt, was = out
    return nd, nw, nt, was == 1
