"""Jit'd public wrappers around the Pallas kernels with ref fallbacks.

On this container (CPU) the Pallas TPU kernels execute in interpret mode;
``impl='auto'`` picks interpret-Pallas only when explicitly requested so unit
economics on CPU stay sane. On a real TPU build, 'pallas' is the default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref

_DEFAULT_IMPL = "ref"  # flipped to "pallas" on TPU backends at import time
try:  # pragma: no cover - depends on runtime platform
    if jax.default_backend() == "tpu":
        _DEFAULT_IMPL = "pallas"
except Exception:  # pragma: no cover
    pass


def default_impl() -> str:
    """The backend-selected kernel implementation ('ref' on CPU, 'pallas'
    on TPU) — lets callers make the same static choice this module makes."""
    return _DEFAULT_IMPL


def append_tile_rows(nb: int, tile: int = 128) -> int:
    """Pool block rows per append-kernel grid step (see kernels/append.py)
    — exposed so callers computing the touched-tile prefetch list agree
    with the kernel's tiling."""
    from .append import append_tile_rows as _atr
    return _atr(nb, tile)


def append_edges(dst, w, ts, wblk, wlane, wval, wd, ww, wts,
                 pstart, psize, pv, tiles=None, n_touched=None,
                 impl: str = "auto"):
    """Fused edge append: slot scatter of (dst, weight, ts) + pre-append
    last-writer pair-liveness probe, bounded to the prefetched ``tiles``
    list (touched pool tiles; the ref oracle is dense and ignores it).
    See ref.append_ref."""
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .append import append_pallas
        return append_pallas(dst, w, ts, wblk, wlane, wval, wd, ww, wts,
                             pstart, psize, pv, tiles, n_touched)
    return _ref.append_ref(dst, w, ts, wblk, wlane, wval, wd, ww, wts,
                           pstart, psize, pv)


def compact_rows(dst, w, ts, size, read_ts=None, impl: str = "auto"):
    """Batched log compaction (paper Alg. 2). See ref.compact_rows_ref."""
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .compact import compact_rows_pallas
        return compact_rows_pallas(dst, w, ts, size, read_ts=read_ts)
    return _ref.compact_rows_ref(dst, w, ts, size, read_ts=read_ts)


def defrag_rows(dst, w, ts, size, keep_all: bool = False,
                n_cap: int | None = None, impl: str = "auto"):
    """Defrag row compactor: last-writer dedup + tombstone drop with
    destination-ASCENDING emission (the streaming rebuild's per-vertex
    pass). ``n_cap`` is the destination-offset universe the kernel's
    bitmaps must cover — callers pass the vertex-table capacity.
    ``keep_all`` (the 'grow' policy) always runs the jnp oracle — it
    keeps every version, which the bitmap kernel cannot express.
    See ref.defrag_rows_ref; returns (dst', w', ts', count, live)."""
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas" and not keep_all:
        from .compact import defrag_rows_pallas
        return defrag_rows_pallas(dst, w, ts, size, n_cap=n_cap)
    return _ref.defrag_rows_ref(dst, w, ts, size, keep_all=keep_all)


def sort_lookup(pools, counts, keys, *, fanout_bits, bit_offsets,
                impl: str = "auto"):
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .sort_lookup import sort_lookup_pallas
        return sort_lookup_pallas(pools, counts, keys, fanout_bits=fanout_bits,
                                  bit_offsets=bit_offsets)
    return _ref.sort_lookup_ref(pools, counts, keys, fanout_bits=fanout_bits,
                                bit_offsets=bit_offsets)


def frontier_expand(owner, dst, valid, frontier_bits, visited_bits,
                    impl: str = "auto"):
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .frontier import frontier_pallas
        return frontier_pallas(owner, dst, valid, frontier_bits, visited_bits)
    return _ref.frontier_ref(owner, dst, valid, frontier_bits, visited_bits)
