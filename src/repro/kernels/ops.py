"""Jit'd public wrappers around the Pallas kernels with ref fallbacks.

On this container (CPU) the Pallas TPU kernels execute in interpret mode;
``impl='auto'`` picks interpret-Pallas only when explicitly requested so unit
economics on CPU stay sane. On a real TPU build, 'pallas' is the default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref

_DEFAULT_IMPL = "ref"  # flipped to "pallas" on TPU backends at import time
try:  # pragma: no cover - depends on runtime platform
    if jax.default_backend() == "tpu":
        _DEFAULT_IMPL = "pallas"
except Exception:  # pragma: no cover
    pass


def compact_rows(dst, w, ts, size, read_ts=None, impl: str = "auto"):
    """Batched log compaction (paper Alg. 2). See ref.compact_rows_ref."""
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .compact import compact_rows_pallas
        return compact_rows_pallas(dst, w, ts, size, read_ts=read_ts)
    return _ref.compact_rows_ref(dst, w, ts, size, read_ts=read_ts)


def sort_lookup(pools, counts, keys, *, fanout_bits, bit_offsets,
                impl: str = "auto"):
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .sort_lookup import sort_lookup_pallas
        return sort_lookup_pallas(pools, counts, keys, fanout_bits=fanout_bits,
                                  bit_offsets=bit_offsets)
    return _ref.sort_lookup_ref(pools, counts, keys, fanout_bits=fanout_bits,
                                bit_offsets=bit_offsets)


def frontier_expand(owner, dst, valid, frontier_bits, visited_bits,
                    impl: str = "auto"):
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .frontier import frontier_pallas
        return frontier_pallas(owner, dst, valid, frontier_bits, visited_bits)
    return _ref.frontier_ref(owner, dst, valid, frontier_bits, visited_bits)
