"""Jit'd public wrappers around the Pallas kernels with ref fallbacks.

On this container (CPU) the Pallas TPU kernels execute in interpret mode;
``impl='auto'`` picks interpret-Pallas only when explicitly requested so unit
economics on CPU stay sane. On a real TPU build, 'pallas' is the default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref

_DEFAULT_IMPL = "ref"  # flipped to "pallas" on TPU backends at import time
try:  # pragma: no cover - depends on runtime platform
    if jax.default_backend() == "tpu":
        _DEFAULT_IMPL = "pallas"
except Exception:  # pragma: no cover
    pass


def default_impl() -> str:
    """The backend-selected kernel implementation ('ref' on CPU, 'pallas'
    on TPU) — lets callers make the same static choice this module makes."""
    return _DEFAULT_IMPL


def append_edges(dst, w, ts, wblk, wlane, wval, wd, ww, wts,
                 pstart, psize, pv, impl: str = "auto"):
    """Fused edge append: slot scatter of (dst, weight, ts) + pre-append
    last-writer pair-liveness probe. See ref.append_ref."""
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .append import append_pallas
        return append_pallas(dst, w, ts, wblk, wlane, wval, wd, ww, wts,
                             pstart, psize, pv)
    return _ref.append_ref(dst, w, ts, wblk, wlane, wval, wd, ww, wts,
                           pstart, psize, pv)


def compact_rows(dst, w, ts, size, read_ts=None, impl: str = "auto"):
    """Batched log compaction (paper Alg. 2). See ref.compact_rows_ref."""
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .compact import compact_rows_pallas
        return compact_rows_pallas(dst, w, ts, size, read_ts=read_ts)
    return _ref.compact_rows_ref(dst, w, ts, size, read_ts=read_ts)


def sort_lookup(pools, counts, keys, *, fanout_bits, bit_offsets,
                impl: str = "auto"):
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .sort_lookup import sort_lookup_pallas
        return sort_lookup_pallas(pools, counts, keys, fanout_bits=fanout_bits,
                                  bit_offsets=bit_offsets)
    return _ref.sort_lookup_ref(pools, counts, keys, fanout_bits=fanout_bits,
                                bit_offsets=bit_offsets)


def frontier_expand(owner, dst, valid, frontier_bits, visited_bits,
                    impl: str = "auto"):
    impl = _DEFAULT_IMPL if impl == "auto" else impl
    if impl == "pallas":
        from .frontier import frontier_pallas
        return frontier_pallas(owner, dst, valid, frontier_bits, visited_bits)
    return _ref.frontier_ref(owner, dst, valid, frontier_bits, visited_bits)
