"""Pallas TPU kernel: log compaction (paper Algorithm 2).

One grid step compacts one vertex's edge array. The duplicate checker is a
**VMEM-resident bitmap** (the paper's segmented bitmap maps 1:1 onto VMEM
words); the reverse scan is a data-dependent sequential loop — exactly the
pattern XLA cannot express but Pallas can, and on TPU it runs from VMEM at
register speed while the next tile streams in.

Per the paper, the bitmap is *unmarked* by re-scanning the processed entries
(O(d), not O(n)) so scratch persists cleanly across grid steps.

TPU target notes: D (edge-array tile width) should be a multiple of 128
lanes; the bitmap covers the vertex-offset universe (n_cap bits -> n_cap/8
bytes of VMEM; 1M vertices = 128 KiB, far under the 16 MiB budget). Validated
here in interpret mode (CPU container) against ``ref.compact_rows_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["compact_rows_pallas", "defrag_rows_pallas"]


def _kernel(dst_ref, w_ref, ts_ref, size_ref, odst_ref, ow_ref, ots_ref,
            ocnt_ref, bitmap):
    D = dst_ref.shape[1]

    # zero the duplicate checker once; thereafter the unmark pass restores it
    @pl.when(pl.program_id(0) == 0)
    def _():
        bitmap[...] = jnp.zeros_like(bitmap)

    # outputs must be fully initialized (empty slots = -1 / 0 / 0)
    odst_ref[...] = jnp.full_like(odst_ref, -1)
    ow_ref[...] = jnp.zeros_like(ow_ref)
    ots_ref[...] = jnp.zeros_like(ots_ref)

    size = size_ref[0, 0]

    def scan(i, cnt):
        j = size - 1 - i                      # reverse scan (most recent first)
        d = dst_ref[0, j]
        word = jnp.right_shift(d, 5)
        bit = jnp.uint32(1) << (d & 31).astype(jnp.uint32)
        seen = (bitmap[word] & bit) != 0
        live = (d >= 0) & ~seen
        emit = live & (w_ref[0, j] != 0)

        @pl.when(emit)
        def _():
            odst_ref[0, pl.ds(cnt, 1)] = d[None]
            ow_ref[0, pl.ds(cnt, 1)] = w_ref[0, j][None]
            ots_ref[0, pl.ds(cnt, 1)] = ts_ref[0, j][None]

        @pl.when(d >= 0)
        def _():
            bitmap[word] = bitmap[word] | bit  # mark visited (even tombstones)

        return cnt + jnp.where(emit, 1, 0)

    cnt = jax.lax.fori_loop(0, size, scan, jnp.int32(0))
    ocnt_ref[0, 0] = cnt

    # unmark pass (paper Alg. 2 lines 9–11): restore bitmap to all-zero
    def unmark(i, _):
        d = dst_ref[0, i]

        @pl.when(d >= 0)
        def _():
            word = jnp.right_shift(d, 5)
            bit = jnp.uint32(1) << (d & 31).astype(jnp.uint32)
            bitmap[word] = bitmap[word] & ~bit

        return 0

    jax.lax.fori_loop(0, size, unmark, 0)


@functools.partial(jax.jit, static_argnames=("n_cap", "interpret"))
def compact_rows_pallas(dst, w, ts, size, read_ts=None, *,
                        n_cap: int | None = None, interpret: bool | None = None):
    """Drop-in for ``ref.compact_rows_ref`` (same outputs, same order)."""
    K, D = dst.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if read_ts is not None:  # MVCC filter applied before the scan
        ok = ts <= jnp.asarray(read_ts, ts.dtype)
        dst = jnp.where(ok, dst, -1)
    if n_cap is None:
        n_cap = 1 << 20  # default bitmap universe (128 KiB VMEM)
    words = (n_cap + 31) // 32

    grid = (K,)
    row = lambda i: (i, 0)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, 1), row),
        ],
        out_specs=[
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, 1), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, D), dst.dtype),
            jax.ShapeDtypeStruct((K, D), w.dtype),
            jax.ShapeDtypeStruct((K, D), ts.dtype),
            jax.ShapeDtypeStruct((K, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((words,), jnp.uint32)],
        interpret=interpret,
    )(dst, w, ts, size.reshape(K, 1).astype(jnp.int32))
    odst, ow, ots, ocnt = out
    return odst, ow, ots, ocnt[:, 0]


# --------------------------------------------------------------------------
# defrag row compactor: the streaming rebuild's per-vertex pass
# --------------------------------------------------------------------------

def _defrag_kernel(dst_ref, w_ref, ts_ref, size_ref, odst_ref, ow_ref,
                   ots_ref, ocnt_ref, seen, seen2, live, prefix):
    """Like the log compactor above, but survivors are emitted sorted by
    destination ASCENDING (the defrag's CSR discipline) instead of
    reverse-scan order. Three passes over the row's O(d) occupied entries
    plus one O(n_cap/32) sweep over the bitmap words:

    1. reverse scan marks the duplicate checker (``seen``) and, for each
       destination's newest non-tombstone entry, the ``live`` bitmap;
    2. a prefix-popcount over ``live`` words turns the bitmap into the
       survivors' emission ranks, and a second reverse scan (deduped via
       ``seen2``) places each winner at
       ``prefix[word] + popcount(live_word & (bit - 1))`` — its
       destination's rank among all live destinations;
    3. the unmark pass (paper Alg. 2 lines 9-11) restores all three
       bitmaps to zero so scratch persists cleanly across grid steps.
    """
    W = live.shape[0]
    size = size_ref[0, 0]

    @pl.when(pl.program_id(0) == 0)
    def _():
        seen[...] = jnp.zeros_like(seen)
        seen2[...] = jnp.zeros_like(seen2)
        live[...] = jnp.zeros_like(live)

    odst_ref[...] = jnp.full_like(odst_ref, -1)
    ow_ref[...] = jnp.zeros_like(ow_ref)
    ots_ref[...] = jnp.zeros_like(ots_ref)

    def scan(i, cnt):
        j = size - 1 - i                      # reverse: most recent first
        d = dst_ref[0, j]
        word = jnp.right_shift(d, 5)
        bit = jnp.uint32(1) << (d & 31).astype(jnp.uint32)
        first = (d >= 0) & ((seen[word] & bit) == 0)
        emit = first & (w_ref[0, j] != 0)

        @pl.when(emit)
        def _():
            live[word] = live[word] | bit

        @pl.when(d >= 0)
        def _():
            seen[word] = seen[word] | bit

        return cnt + jnp.where(emit, 1, 0)

    cnt = jax.lax.fori_loop(0, size, scan, jnp.int32(0))
    ocnt_ref[0, 0] = cnt

    def pre(wi, acc):
        prefix[wi] = acc
        return acc + jax.lax.population_count(live[wi]).astype(jnp.int32)

    jax.lax.fori_loop(0, W, pre, jnp.int32(0))

    def place(i, _):
        j = size - 1 - i
        d = dst_ref[0, j]
        word = jnp.right_shift(d, 5)
        bit = jnp.uint32(1) << (d & 31).astype(jnp.uint32)
        winner = (d >= 0) & ((seen2[word] & bit) == 0) & \
            ((live[word] & bit) != 0)

        @pl.when(winner)
        def _():
            rank = prefix[word] + jax.lax.population_count(
                live[word] & (bit - 1)).astype(jnp.int32)
            odst_ref[0, pl.ds(rank, 1)] = d[None]
            ow_ref[0, pl.ds(rank, 1)] = w_ref[0, j][None]
            ots_ref[0, pl.ds(rank, 1)] = ts_ref[0, j][None]

        @pl.when(d >= 0)
        def _():
            seen2[word] = seen2[word] | bit

        return 0

    jax.lax.fori_loop(0, size, place, 0)

    def unmark(i, _):
        d = dst_ref[0, i]

        @pl.when(d >= 0)
        def _():
            word = jnp.right_shift(d, 5)
            bit = jnp.uint32(1) << (d & 31).astype(jnp.uint32)
            seen[word] = seen[word] & ~bit
            seen2[word] = seen2[word] & ~bit
            live[word] = live[word] & ~bit

        return 0

    jax.lax.fori_loop(0, size, unmark, 0)


@functools.partial(jax.jit, static_argnames=("n_cap", "interpret"))
def defrag_rows_pallas(dst, w, ts, size, *, n_cap: int | None = None,
                       interpret: bool | None = None):
    """Drop-in for ``ref.defrag_rows_ref`` (dedup mode only — the 'grow'
    policy's keep-everything variant stays on the jnp oracle). Returns
    (dst', w', ts', count, live) with live == count: dedup mode keeps
    exactly the live pairs."""
    K, D = dst.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if n_cap is None:
        n_cap = 1 << 20
    words = (n_cap + 31) // 32

    grid = (K,)
    row = lambda i: (i, 0)
    out = pl.pallas_call(
        _defrag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, 1), row),
        ],
        out_specs=[
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, D), row),
            pl.BlockSpec((1, 1), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, D), dst.dtype),
            jax.ShapeDtypeStruct((K, D), w.dtype),
            jax.ShapeDtypeStruct((K, D), ts.dtype),
            jax.ShapeDtypeStruct((K, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((words,), jnp.uint32),
                        pltpu.VMEM((words,), jnp.uint32),
                        pltpu.VMEM((words,), jnp.uint32),
                        pltpu.VMEM((words,), jnp.int32)],
        interpret=interpret,
    )(dst, w, ts, size.reshape(K, 1).astype(jnp.int32))
    odst, ow, ots, ocnt = out
    return odst, ow, ots, ocnt[:, 0], ocnt[:, 0]
