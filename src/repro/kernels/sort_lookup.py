"""Pallas TPU kernel: fused SORT descent (batched vertex-ID lookup).

The lookup is ``l`` dependent gathers. XLA materializes each layer's node-id
vector in HBM between gathers; the fused kernel keeps the whole descent in
registers/VMEM — keys stream in as tiles, node pools stay in HBM/ANY and are
hit with scalar dynamic loads (TPU's scalar core drives the address chase
while the next key tile is prefetched).

Layer structure (fan-outs, bit offsets) is static; the kernel is specialized
per SORT configuration. Validated in interpret mode vs ``ref.sort_lookup_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUMemorySpace -> MemorySpace in newer pallas releases
_ANY_MEMSPACE = getattr(pltpu, "MemorySpace",
                        getattr(pltpu, "TPUMemorySpace", None)).ANY

__all__ = ["sort_lookup_pallas"]


def _make_kernel(layers: int, fanout_bits, bit_offsets, tile: int):
    def kernel(*refs):
        keys_ref = refs[0]
        pool_refs = refs[1:1 + layers]
        out_ref = refs[1 + layers]

        def body(k, _):
            hi = keys_ref[k, 0]
            lo = keys_ref[k, 1]
            node = jnp.int32(0)
            valid = jnp.bool_(True)
            for i in range(layers):
                a, boff = fanout_bits[i], bit_offsets[i]
                mask = jnp.uint32((1 << a) - 1)
                if boff >= 32:
                    idx = (hi >> jnp.uint32(boff - 32)) & mask
                elif boff + a <= 32:
                    idx = (lo >> jnp.uint32(boff)) & mask
                else:
                    lo_bits = 32 - boff
                    idx = (((hi & jnp.uint32((1 << (boff + a - 32)) - 1))
                            << jnp.uint32(lo_bits)) | (lo >> jnp.uint32(boff)))
                slot = node * (1 << a) + idx.astype(jnp.int32)
                child = pool_refs[i][pl.ds(slot, 1)][0]
                child = jnp.where(valid, child, -1)
                valid = child >= 0
                node = jnp.maximum(child, 0)
            out_ref[pl.ds(k, 1)] = jnp.where(valid, node, -1)[None]
            return 0

        jax.lax.fori_loop(0, tile, body, 0)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("fanout_bits", "bit_offsets", "tile",
                                    "interpret"))
def sort_lookup_pallas(pools, counts, keys, *, fanout_bits, bit_offsets,
                       tile: int = 256, interpret: bool | None = None):
    """(B, 2) uint32 keys -> int32 offsets. B must be a multiple of ``tile``
    (callers pad; the facade's batches are power-of-two sized)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    layers = len(fanout_bits)
    B = keys.shape[0]
    tile = min(tile, B)
    assert B % tile == 0, "pad the key batch to a multiple of the tile"
    grid = (B // tile,)

    in_specs = [pl.BlockSpec((tile, 2), lambda i: (i, 0))]
    # node pools stay unblocked in ANY memory (HBM); scalar loads chase them
    for _ in range(layers):
        in_specs.append(pl.BlockSpec(memory_space=_ANY_MEMSPACE))

    out = pl.pallas_call(
        _make_kernel(layers, tuple(fanout_bits), tuple(bit_offsets), tile),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(keys, *pools)
    return out
