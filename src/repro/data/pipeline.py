"""Data pipeline: deterministic synthetic token stream, a RadixGraph-backed
random-walk stream (dynamic-graph pretraining — the paper's structure feeding
the LM substrate), background prefetch, and sharded host->device placement.

Every stream is checkpointable: ``state()`` returns a small dict stored in
the checkpoint metadata; ``restore(state)`` resumes bit-exactly.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


class TokenStream:
    """Deterministic synthetic LM batches (counter-keyed PRNG: any step can
    be regenerated, so resume == replay)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.step = 0

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def state_for(self, consumed: int) -> Dict:
        """Resume state after ``consumed`` batches were TRAINED on (use this
        under a Prefetcher, which generates ahead of consumption)."""
        return {"step": consumed, "seed": self.seed}

    def restore(self, st: Dict):
        self.step = int(st["step"])
        self.seed = int(st["seed"])

    def __iter__(self) -> Iterator[Dict]:
        return self

    def __next__(self) -> Dict:
        rng = np.random.default_rng((self.seed << 32) | self.step)
        toks = rng.integers(0, self.vocab, (self.batch, self.seq + 1),
                            dtype=np.int32)
        # inject learnable bigram structure so loss decreases measurably
        odd = toks[:, 1::2].shape[1]
        toks[:, 1::2] = (toks[:, 0::2][:, :odd] * 31 + 7) % self.vocab
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class GraphWalkStream:
    """Random-walk sequences over a RadixGraph snapshot: the dynamic graph
    store *is* the corpus (vertex offsets -> token ids). Re-snapshot with
    ``refresh`` as the graph ingests updates (streaming pretraining)."""

    def __init__(self, graph, vocab: int, batch: int, seq: int, seed: int = 0):
        self.graph, self.vocab = graph, vocab
        self.batch, self.seq, self.seed = batch, seq, seed
        self.step = 0
        self.refresh()

    def refresh(self):
        snap = self.graph.snapshot()
        self.indptr = np.asarray(snap.indptr)
        self.dst = np.asarray(snap.dst)
        self.active = np.nonzero(np.asarray(snap.active))[0]

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def state_for(self, consumed: int) -> Dict:
        return {"step": consumed, "seed": self.seed}

    def restore(self, st: Dict):
        self.step = int(st["step"])
        self.seed = int(st["seed"])

    def __next__(self) -> Dict:
        rng = np.random.default_rng((self.seed << 32) | self.step)
        B, S = self.batch, self.seq + 1
        walks = np.zeros((B, S), np.int32)
        cur = rng.choice(self.active, B)
        walks[:, 0] = cur
        for t in range(1, S):
            lo, hi = self.indptr[cur], self.indptr[cur + 1]
            deg = hi - lo
            nxt = np.where(
                deg > 0,
                self.dst[np.minimum(lo + (rng.random(B) * np.maximum(deg, 1)
                                          ).astype(np.int64), hi - 1)],
                rng.choice(self.active, B))
            cur = nxt
            walks[:, t] = cur
        toks = walks % self.vocab
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        return self


class Prefetcher:
    """Background-thread prefetch of host batches (overlaps data generation
    with device compute)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.it = it
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.err: Optional[BaseException] = None
        self._stop = False
        self.t = threading.Thread(target=self._work, daemon=True)
        self.t.start()

    def _work(self):
        try:
            for item in self.it:
                if self._stop:
                    return
                self.q.put(item)
        except BaseException as e:  # noqa: BLE001
            self.err = e
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            if self.err:
                raise self.err
            raise StopIteration
        return item

    def close(self):
        self._stop = True


def shard_batch(batch: Dict, mesh, batch_axes=("pod", "data")):
    """Host batch -> device arrays sharded on the batch axes."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def put(x):
        spec = P(axes) if x.ndim >= 1 and x.shape[0] % _size(mesh, axes) == 0 \
            else P()
        return jax.device_put(x, NamedSharding(mesh, spec))

    return {k: put(np.asarray(v)) for k, v in batch.items()}


def _size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
