from .pipeline import (TokenStream, GraphWalkStream, Prefetcher,
                       shard_batch)

__all__ = ["TokenStream", "GraphWalkStream", "Prefetcher", "shard_batch"]
