"""whisper-small [audio] — encoder-decoder backbone (arXiv:2212.04356).
Conv/audio frontend is a STUB: input_specs supplies precomputed frame
embeddings (B, S, d) to the encoder."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-small", family="encdec", layers=12, enc_layers=12,
    dec_layers=12, d_model=768, n_heads=12, kv_heads=12, d_ff=3072,
    vocab=51865, act="gelu", rope_theta=10000.0, tie_embeddings=True,
)

SMOKE = CONFIG.scaled(layers=2, enc_layers=2, dec_layers=2, d_model=64,
                      n_heads=4, kv_heads=4, d_ff=128, vocab=128,
                      param_dtype="float32", compute_dtype="float32")

SKIPS = {"long_500k": "full attention enc-dec: 524288-token decode cache is "
                      "quadratic-history; sub-quadratic attention required"}
