"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution backbone
(arXiv:2409.12191). Vision frontend is a stub: input_specs supplies
precomputed patch-grid M-RoPE position ids alongside token ids."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-vl-2b", family="vlm", layers=28, d_model=1536,
    n_heads=12, kv_heads=2, d_ff=8960, vocab=151936,
    qkv_bias=True, pos="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1e6, tie_embeddings=True,
)

SMOKE = CONFIG.scaled(layers=2, d_model=96, n_heads=6, kv_heads=2, d_ff=256,
                      vocab=128, mrope_sections=(4, 2, 2),
                      param_dtype="float32", compute_dtype="float32")

SKIPS = {"long_500k": "full attention (no windowing in published config): "
                      "524288-token decode cache is quadratic-history; "
                      "sub-quadratic attention required per assignment"}
