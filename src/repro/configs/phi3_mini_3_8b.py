"""phi3-mini-3.8b [dense] — RoPE SwiGLU, MHA (kv == q heads)
(arXiv:2404.14219)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="phi3-mini-3.8b", family="dense", layers=32, d_model=3072,
    n_heads=32, kv_heads=32, d_ff=8192, vocab=32064,
    rope_theta=10000.0, tie_embeddings=False,
)

SMOKE = CONFIG.scaled(layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
                      vocab=128, param_dtype="float32",
                      compute_dtype="float32")

SKIPS = {"long_500k": "pure full attention: sub-quadratic required"}
