"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent :
1 local-attention pattern (arXiv:2402.19427)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="recurrentgemma-9b", family="hybrid", layers=38, d_model=4096,
    n_heads=16, kv_heads=1, d_ff=12288, vocab=256000,
    pattern=("rec", "rec", "attn"), window=2048, lru_width=4096,
    conv_width=4, rope_theta=10000.0, tie_embeddings=True,
    subquadratic=True,  # RG-LRU state + 2048-window local attention
)

SMOKE = CONFIG.scaled(layers=6, d_model=64, n_heads=4, kv_heads=1, d_ff=128,
                      vocab=128, lru_width=64, window=16,
                      param_dtype="float32", compute_dtype="float32")

SKIPS = {}
