"""mamba2-1.3b [ssm] — SSD, attention-free (arXiv:2405.21060)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="mamba2-1.3b", family="ssm", layers=48, d_model=2048,
    n_heads=0, kv_heads=0, d_ff=0, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    conv_width=4, tie_embeddings=True, pos="none",
    subquadratic=True,
)

SMOKE = CONFIG.scaled(layers=2, d_model=64, vocab=128, ssm_state=16,
                      ssm_head_dim=16, ssm_chunk=8,
                      param_dtype="float32", compute_dtype="float32")

SKIPS = {}  # SSM decode state is O(1) in context — long_500k runs
