"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, early fusion
(hf:meta-llama/Llama-4 family). Per the assignment spec every layer is MoE
with per-expert d_ff=8192; the resulting total parameter count from these
published dims is reported by api.param_counts (the marketing '400b' name is
nominal)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="llama4-maverick-400b-a17b", family="moe", layers=48, d_model=5120,
    n_heads=40, kv_heads=8, d_ff=8192, vocab=202048,
    n_experts=128, top_k=1, capacity_factor=1.25,
    rope_theta=500000.0, tie_embeddings=False,
)

SMOKE = CONFIG.scaled(layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=96,
                      vocab=128, n_experts=8, top_k=1,
                      param_dtype="float32", compute_dtype="float32")

SKIPS = {"long_500k": "pure full attention: sub-quadratic required"}
