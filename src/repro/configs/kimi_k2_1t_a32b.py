"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE, 384 experts top-8
(paper-table spec). head_dim 112 (7168/64)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="kimi-k2-1t-a32b", family="moe", layers=61, d_model=7168,
    n_heads=64, kv_heads=8, d_ff=2048, vocab=163840,
    n_experts=384, top_k=8, capacity_factor=1.25,
    rope_theta=50000.0, tie_embeddings=False,
)

SMOKE = CONFIG.scaled(layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=64,
                      vocab=128, n_experts=8, top_k=2,
                      param_dtype="float32", compute_dtype="float32")

SKIPS = {"long_500k": "pure full attention: sub-quadratic required"}
