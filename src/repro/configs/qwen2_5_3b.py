"""qwen2.5-3b [dense] — GQA with QKV bias (hf:Qwen/Qwen2.5 family)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2.5-3b", family="dense", layers=36, d_model=2048,
    n_heads=16, kv_heads=2, d_ff=11008, vocab=151936,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
)

SMOKE = CONFIG.scaled(layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=160,
                      vocab=128, param_dtype="float32",
                      compute_dtype="float32")

SKIPS = {"long_500k": "pure full attention: sub-quadratic required"}
