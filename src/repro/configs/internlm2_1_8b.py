"""internlm2-1.8b [dense] — GQA (arXiv:2403.17297)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="internlm2-1.8b", family="dense", layers=24, d_model=2048,
    n_heads=16, kv_heads=8, d_ff=8192, vocab=92544,
    rope_theta=1e6, tie_embeddings=False,
)

SMOKE = CONFIG.scaled(layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
                      vocab=128, param_dtype="float32",
                      compute_dtype="float32")

SKIPS = {"long_500k": "pure full attention: sub-quadratic required"}
