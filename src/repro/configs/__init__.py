"""Assigned-architecture registry: 10 archs x their shape sets (40 cells).

Each ``<arch>.py`` defines CONFIG (exact published shape), SMOKE (reduced
same-family config for CPU smoke tests) and SKIPS (shape-cell skips with
rationale, per the assignment rules).
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.config import ModelConfig

# shape id -> (kind, seq_len, global_batch)
SHAPES: Dict[str, Tuple[str, int, int]] = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}

_MODULES = {
    "mamba2-1.3b": "mamba2_1_3b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-small": "whisper_small",
    "qwen2.5-3b": "qwen2_5_3b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "internlm2-1.8b": "internlm2_1_8b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
}

ARCH_IDS = list(_MODULES)


def get_arch(arch_id: str):
    """Returns the arch module (CONFIG, SMOKE, SKIPS)."""
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod


def get_config(arch_id: str) -> ModelConfig:
    return get_arch(arch_id).CONFIG


def cells():
    """All (arch, shape) cells with skip rationale where applicable."""
    out = []
    for a in ARCH_IDS:
        mod = get_arch(a)
        skips = getattr(mod, "SKIPS", {})
        for s in SHAPES:
            out.append((a, s, skips.get(s)))
    return out
