"""deepseek-coder-33b [dense] — llama-arch GQA (arXiv:2401.14196)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch="deepseek-coder-33b", family="dense", layers=62, d_model=7168,
    n_heads=56, kv_heads=8, d_ff=19200, vocab=32256,
    rope_theta=10000.0, tie_embeddings=False,
)

SMOKE = CONFIG.scaled(layers=2, d_model=64, n_heads=8, kv_heads=2, d_ff=192,
                      vocab=128, param_dtype="float32",
                      compute_dtype="float32")

SKIPS = {"long_500k": "pure full attention: sub-quadratic required"}
