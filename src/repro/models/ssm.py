"""Mamba2 SSD (state-space duality) block — chunked, MXU-friendly.

Implements the block decomposition of arXiv:2405.21060: within a chunk the
output is a masked quadratic form (matmuls — maps onto the MXU); across
chunks a single recurrent state (B_heads, P, N) is passed through a scan.
Decode is the O(1) recurrence h = decay·h + dt·B⊗x.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SSMCache(NamedTuple):
    h: jnp.ndarray        # (B, H, P, N) recurrent state
    conv: jnp.ndarray     # (B, W-1, conv_dim) conv tail


def ssd_chunked(x, dt, A, B_, C_, D, chunk: int):
    """x: (B, S, H, P); dt: (B, S, H) (softplus applied); A: (H,) < 0;
    B_, C_: (B, S, N); D: (H,). Returns y (B, S, H, P) and final state
    (B, H, P, N)."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    nc = (S + chunk - 1) // chunk
    pad = nc * chunk - S
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Bp = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
    Cp = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))

    xc = xp.reshape(Bsz, nc, chunk, H, P)
    dtc = dtp.reshape(Bsz, nc, chunk, H)
    Bc = Bp.reshape(Bsz, nc, chunk, N)
    Cc = Cp.reshape(Bsz, nc, chunk, N)

    dA = dtc * A[None, None, None, :]            # (B, nc, L, H), <= 0
    cs = jnp.cumsum(dA, axis=2)                  # within-chunk cumulative

    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for j <= i
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # (B,nc,L,L,H)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    Lmat = jnp.where(causal, jnp.exp(diff), 0.0)
    G = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32),
                   Bc.astype(jnp.float32))       # (B,nc,L,L)
    M = G[..., None] * Lmat                      # (B,nc,L,L,H)
    xdt = xc.astype(jnp.float32) * dtc[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xdt)

    # chunk states: S_c = sum_j exp(cs_last - cs_j) * dt_j * B_j x_j^T
    decay_tail = jnp.exp(cs[:, :, -1:, :] - cs)  # (B,nc,L,H)
    SB = jnp.einsum("bclh,bcln,bclhp->bchpn", decay_tail * dtc, Bc.astype(
        jnp.float32), xc.astype(jnp.float32))

    # inter-chunk scan: h_{c} = exp(sum dA_c) * h_{c-1} + S_c
    chunk_decay = jnp.exp(cs[:, :, -1, :])       # (B,nc,H)

    def scan_fn(h, inp):
        dcy, s = inp
        h_new = h * dcy[..., None, None] + s
        return h_new, h

    dcy_t = jnp.moveaxis(chunk_decay, 1, 0)      # (nc,B,H)
    s_t = jnp.moveaxis(SB, 1, 0)                 # (nc,B,H,P,N)
    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_final, h_prevs = jax.lax.scan(scan_fn, h0, (dcy_t, s_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)        # (B,nc,H,P,N) state before chunk

    # inter-chunk contribution: y += C_i exp(cs_i) h_prev
    in_decay = jnp.exp(cs)                        # (B,nc,L,H)
    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc.astype(jnp.float32),
                         h_prevs, in_decay)

    y = y_intra + y_inter + xc.astype(jnp.float32) * D[None, None, None, :,
                                                       None]
    y = y.reshape(Bsz, nc * chunk, H, P)[:, :S]
    return y.astype(x.dtype), h_final


def ssd_decode_step(x, dt, A, B_, C_, D, h):
    """One-token recurrence. x: (B, H, P); dt: (B, H); B_, C_: (B, N);
    h: (B, H, P, N). Returns (y, h')."""
    dA = jnp.exp(dt * A[None, :])                                # (B,H)
    hB = jnp.einsum("bh,bn,bhp->bhpn", dt, B_.astype(jnp.float32),
                    x.astype(jnp.float32))
    h = h * dA[..., None, None] + hB
    y = jnp.einsum("bn,bhpn->bhp", C_.astype(jnp.float32), h)
    y = y + x.astype(jnp.float32) * D[None, :, None]
    return y.astype(x.dtype), h


def causal_conv(x, w, cache=None):
    """Depthwise causal conv1d. x: (B, S, C); w: (W, C). cache: (B, W-1, C)
    from the previous step (decode). Returns (y, new_cache)."""
    W = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([cache, x], axis=1)
    y = sum(xx[:, i:i + x.shape[1]] * w[i][None, None] for i in range(W))
    new_cache = xx[:, -(W - 1):] if W > 1 else cache
    return jax.nn.silu(y), new_cache
