"""Shared model layers: norms, RoPE/M-RoPE, chunked flash attention (GQA,
causal/bidirectional/sliding-window), SwiGLU/GELU FFN, MoE dispatch.

Pure functions over explicit param pytrees. Every init helper also emits a
*logical sharding spec* pytree (tuples of logical axis names parallel to the
array dims) consumed by ``repro.dist.sharding``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# init helpers (params + logical specs)
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, spec, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * scale, spec


def zeros_init(shape, dtype, spec):
    return jnp.zeros(shape, dtype), spec


def split_tree(pairs):
    """dict of name -> (array, spec)  ->  (params dict, specs dict)."""
    params = {k: v[0] if isinstance(v, tuple) else split_tree(v)[0]
              for k, v in pairs.items()}
    specs = {k: v[1] if isinstance(v, tuple) else split_tree(v)[1]
             for k, v in pairs.items()}
    return params, specs


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-6):
    # stats in f32, products in the compute dtype: keeps every
    # activation-shaped tensor (and its cotangent) in bf16 so TP collectives
    # move half the bytes (§Perf: the f32 upcast was being gathered)
    x32 = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return x * r.astype(x.dtype) * g.astype(x.dtype)


def swiglu(x, w1, w3, w2):
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def gelu_mlp(x, w1, w2):
    return jax.nn.gelu(x @ w1) @ w2


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta, sections):
    """Multimodal RoPE (qwen2-vl): positions3 (3, ..., S) for (t, h, w);
    frequency planes are partitioned into ``sections`` (halves of Dh/2)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (Dh/2,)
    sec = jnp.cumsum(jnp.asarray((0,) + tuple(sections)))
    plane = jnp.clip(jnp.searchsorted(sec[1:], jnp.arange(hd // 2),
                                      side="right"), 0, 2)  # (Dh/2,)
    pos = jnp.moveaxis(positions3.astype(jnp.float32)[plane], 0, -1)
    ang = pos * inv  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked flash attention (GQA; causal / bidirectional / sliding window)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    q_offset: int = 0):
    """Online-softmax attention with double chunking (lax.scan in both axes).

    q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh) with Hq % Hkv == 0.
    ``q_offset`` is the absolute position of q[0] (prefill continuation).
    Memory high-water: (B, Hq, q_chunk, kv_chunk) scores — VMEM-tileable.
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = (Sq + q_chunk - 1) // q_chunk
    nk = (Skv + kv_chunk - 1) // kv_chunk
    pad_q = nq * q_chunk - Sq
    pad_k = nk * kv_chunk - Skv
    scale = 1.0 / np.sqrt(Dh)

    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qs = qp.reshape(B, nq, q_chunk, Hq, Dh).transpose(1, 0, 3, 2, 4)
    ks = kp.reshape(B, nk, kv_chunk, Hkv, Dh).transpose(1, 0, 3, 2, 4)
    vs = vp.reshape(B, nk, kv_chunk, Hkv, Dh).transpose(1, 0, 3, 2, 4)
    # qs: (nq, B, Hq, qc, Dh); ks/vs: (nk, B, Hkv, kc, Dh)

    kv_valid = jnp.arange(nk * kv_chunk) < Skv

    def q_step(_, qi_q):
        qi, qblk = qi_q  # qblk (B, Hq, qc, Dh)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            kg = jnp.repeat(kblk, G, axis=1)  # (B, Hq, kc, Dh)
            vg = jnp.repeat(vblk, G, axis=1)
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk.astype(jnp.float32),
                           kg.astype(jnp.float32)) * scale
            mask = kv_valid[ki * kv_chunk + jnp.arange(kv_chunk)][None, :]
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vg.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hq, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hq, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hq, q_chunk, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    # outs: (nq, B, Hq, qc, Dh) -> (B, Sq, Hq, Dh)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * q_chunk, Hq, Dh)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None):
    """Single-token decode: q (B, 1, Hq, Dh); caches (B, Smax, Hkv, Dh).

    cache_len: (B,) valid prefix length (the new token's position)."""
    B, _, Hq, Dh = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(Dh)
    pos = jnp.arange(Smax)
    mask = pos[None, :] < cache_len[:, None]           # (B, Smax)
    if window is not None:
        mask = mask & (pos[None, :] > cache_len[:, None] - window)
    qh = q[:, 0].reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MoE (token-choice top-k, capacity-based, sort-free dispatch)
# ---------------------------------------------------------------------------

def moe_ffn(x, router_w, w1, w3, w2, *, top_k: int, capacity_factor: float,
            dtype):
    """x: (B, S, d); router_w: (d, E); w1/w3: (E, d, f); w2: (E, f, d).

    Sort-based capacity dispatch: tokens pick top-k experts; each expert
    serves at most C tokens (overflow dropped, standard Switch behaviour).
    With experts sharded on the EP axis, XLA lowers the dispatch scatter to
    an all_to_all.
    """
    B, S, d = x.shape
    E = router_w.shape[1]
    T = B * S
    xf = x.reshape(T, d)
    logits = (xf.astype(jnp.float32) @ router_w.astype(jnp.float32))
    gval, gidx = jax.lax.top_k(logits, top_k)          # (T, k)
    gates = jax.nn.softmax(gval, axis=-1)

    C = max(1, int(np.ceil(T * top_k / E * capacity_factor)))
    flat_e = gidx.reshape(-1)                          # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), top_k)
    flat_g = gates.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    stt = flat_t[order]
    sg = flat_g[order]
    # rank within expert (segmented iota)
    idx = jnp.arange(T * top_k)
    first = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    seg_start = jax.lax.cummax(jnp.where(first, idx, 0))
    rank = idx - seg_start
    keepm = rank < C
    slot = jnp.where(keepm, se * C + rank, E * C)

    buf = jnp.zeros((E * C, d), dtype).at[slot].set(xf[stt].astype(dtype),
                                                    mode="drop")
    buf = buf.reshape(E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1.astype(dtype))) * \
        jnp.einsum("ecd,edf->ecf", buf, w3.astype(dtype))
    y = jnp.einsum("ecf,efd->ecd", h, w2.astype(dtype)).reshape(E * C, d)

    gathered = y[jnp.clip(slot, 0, E * C - 1)]
    contrib = jnp.where(keepm[:, None], gathered * sg[:, None].astype(dtype),
                        0)
    out = jnp.zeros((T, d), dtype).at[stt].add(contrib)
    aux = _load_balance_loss(logits, gidx, E)
    return out.reshape(B, S, d), aux


def _load_balance_loss(logits, gidx, E):
    probs = jax.nn.softmax(logits, axis=-1)
    pe = jnp.mean(probs, axis=0)
    hits = jnp.zeros((E,), jnp.float32).at[gidx.reshape(-1)].add(1.0)
    fe = hits / jnp.maximum(jnp.sum(hits), 1.0)
    return E * jnp.sum(pe * fe)
