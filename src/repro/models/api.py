"""Public model API: build_model(cfg) -> Model (init / train_loss / prefill /
decode / init_cache / input_specs) + exact parameter accounting.

Shape-cell semantics (assignment):
  train_*   -> train_step lowering (loss + grads happen in repro.train)
  prefill_* -> prefill(params, tokens, cache): full forward, builds cache,
               returns last-position logits
  decode_*  -> decode(params, token, cache, pos): ONE new token against a
               KV/state cache of seq_len
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import lm


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable          # key -> params
    logical_specs: Any      # pytree of logical axis tuples (parallel to params)
    train_loss: Callable    # (params, batch) -> scalar loss
    prefill: Callable       # (params, batch, cache) -> (logits, cache)
    decode: Callable        # (params, batch, cache) -> (logits, cache)
    init_cache: Callable    # (batch, smax) -> cache pytree


def _cache_struct(cfg: ModelConfig, B: int, smax: int):
    dt = cfg.cdt
    hd, Hkv = cfg.hd, cfg.kv_heads
    Lc = cfg.layers
    if cfg.family in ("dense", "vlm", "moe"):
        s = smax if cfg.window is None else min(smax, cfg.window)
        return (jnp.zeros((Lc, B, s, Hkv, hd), dt),
                jnp.zeros((Lc, B, s, Hkv, hd), dt),
                jnp.zeros((Lc, B), jnp.int32))
    if cfg.family == "ssm":
        din = cfg.ssm_expand * cfg.d_model
        H = cfg.ssm_heads or (din // cfg.ssm_head_dim)
        P = din // H
        conv_dim = din + 2 * cfg.ssm_state
        from .ssm import SSMCache
        return SSMCache(
            h=jnp.zeros((Lc, B, H, P, cfg.ssm_state), jnp.float32),
            conv=jnp.zeros((Lc, B, cfg.conv_width - 1, conv_dim), dt))
    if cfg.family == "hybrid":
        unit = len(cfg.pattern)
        G = cfg.layers // unit
        R = sum(1 for t in cfg.pattern if t == "rec")
        A = unit - R
        rest = cfg.layers - G * unit
        Dr = cfg.lru_width or cfg.d_model
        W = min(smax, cfg.window or smax)
        g = ((jnp.zeros((G, R, B, Dr), jnp.float32),
              jnp.zeros((G, R, B, cfg.conv_width - 1, Dr), dt)),
             (jnp.zeros((G, A, B, W, Hkv, hd), dt),
              jnp.zeros((G, A, B, W, Hkv, hd), dt),
              jnp.zeros((G, A, B), jnp.int32)))
        t = None
        if rest:
            t = (jnp.zeros((rest, B, Dr), jnp.float32),
                 jnp.zeros((rest, B, cfg.conv_width - 1, Dr), dt))
        return (g, t)
    if cfg.family == "encdec":
        Ld = cfg.dec_layers
        return (jnp.zeros((Ld, B, smax, Hkv, hd), dt),
                jnp.zeros((Ld, B, smax, Hkv, hd), dt),
                jnp.zeros((Ld, B), jnp.int32))
    raise ValueError(cfg.family)


def shapes_and_logical(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical spec tree) without allocating."""
    box = {}

    def f(k):
        p, s = lm.init_params(cfg, k)
        box["specs"] = s      # plain-Python side channel; runs once at trace
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["specs"]


def build_model(cfg: ModelConfig) -> Model:
    def init(key):
        params, _ = lm.init_params(cfg, key)
        return params

    _, logical = shapes_and_logical(cfg)

    def train_loss(params, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        pos = batch.get("positions")
        if pos is None:
            pos = lm.make_positions(cfg, tokens)
        enc_out = None
        if cfg.family == "encdec":
            enc_out = lm.encode(cfg, params, batch["frames"])
        h, _, aux = lm.forward(cfg, params, tokens, pos, "train",
                               enc_out=enc_out)
        loss = lm.xent_chunked(cfg, params, h, labels)
        return loss + 0.01 * aux

    def prefill(params, batch, cache):
        tokens = batch["tokens"]
        pos = batch.get("positions")
        if pos is None:
            pos = lm.make_positions(cfg, tokens)
        enc_out = None
        if cfg.family == "encdec":
            enc_out = lm.encode(cfg, params, batch["frames"])
        h, cache, _ = lm.forward(cfg, params, tokens, pos, "prefill",
                                 cache=cache, enc_out=enc_out)
        logits = lm._unembed(cfg, params, h[:, -1:])[:, 0]
        return logits, cache

    def decode(params, batch, cache):
        token = batch["token"]            # (B,)
        pos = batch["pos"]                # (B,) absolute position
        tokens = token[:, None]
        if cfg.pos == "mrope":
            p3 = batch.get("positions")
            posx = p3 if p3 is not None else jnp.stack([pos[:, None]] * 3)
        else:
            posx = pos[:, None]
        enc_out = batch.get("enc_out")
        h, cache, _ = lm.forward(cfg, params, tokens, posx, "decode",
                                 cache=cache, enc_out=enc_out)
        logits = lm._unembed(cfg, params, h[:, -1:])[:, 0]
        return logits, cache

    return Model(cfg=cfg, init=init, logical_specs=logical,
                 train_loss=train_loss, prefill=prefill, decode=decode,
                 init_cache=functools.partial(_cache_struct, cfg))


# ---------------------------------------------------------------------------
# accounting & input specs
# ---------------------------------------------------------------------------

def param_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(total, active) parameter counts from real init shapes."""
    shapes, _ = shapes_and_logical(cfg)
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        if any("we" in getattr(k, "key", "") for k in path):
            expert += n
    if cfg.family == "moe" and cfg.n_experts:
        active = total - expert + expert * cfg.top_k // cfg.n_experts
    else:
        active = total
    return total, active


def input_specs(cfg: ModelConfig, kind: str, seq: int, batch: int) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    kind: 'train' | 'prefill' | 'decode'. Frontends are stubs: [audio]
    supplies precomputed frame embeddings, [vlm] supplies M-RoPE grids.
    """
    i32 = jnp.int32
    f32 = jnp.float32
    S, B = seq, batch
    sd = jax.ShapeDtypeStruct
    if kind == "train":
        d = {"tokens": sd((B, S), i32), "labels": sd((B, S), i32)}
        if cfg.pos == "mrope":
            d["positions"] = sd((3, B, S), i32)
        if cfg.family == "encdec":
            d["frames"] = sd((B, S, cfg.d_model), cfg.cdt)
        return d
    if kind == "prefill":
        d = {"tokens": sd((B, S), i32)}
        if cfg.pos == "mrope":
            d["positions"] = sd((3, B, S), i32)
        if cfg.family == "encdec":
            d["frames"] = sd((B, S, cfg.d_model), cfg.cdt)
        return d
    if kind == "decode":
        d = {"token": sd((B,), i32), "pos": sd((B,), i32)}
        if cfg.pos == "mrope":
            d["positions"] = sd((3, B, 1), i32)
        if cfg.family == "encdec":
            d["enc_out"] = sd((B, min(S, 4096), cfg.d_model), cfg.cdt)
        return d
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, batch: int, smax: int):
    return jax.eval_shape(lambda: _cache_struct(cfg, batch, smax))
