"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t),
a_t = exp(-c · softplus(Λ) · r_t),  r/i = sigmoid gates.

Training/prefill uses an associative scan (log-depth on TPU); decode is the
O(1) recurrence. The temporal-conv front and the sliding-window attention
sibling block live in lm.py's hybrid assembly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

C_FACTOR = 8.0


def rglru_scan(x, r, i, lam):
    """x, r, i: (B, S, D); lam: (D,) raw Λ. Returns (y, final_h)."""
    a = jnp.exp(-C_FACTOR * jax.nn.softplus(lam)[None, None] *
                jax.nn.sigmoid(r.astype(jnp.float32)))
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        jax.nn.sigmoid(i.astype(jnp.float32)) * x.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return bb.astype(x.dtype), bb[:, -1].astype(jnp.float32)


def rglru_step(x, r, i, lam, h):
    """One-token step. x, r, i: (B, D); h: (B, D) fp32."""
    a = jnp.exp(-C_FACTOR * jax.nn.softplus(lam)[None] *
                jax.nn.sigmoid(r.astype(jnp.float32)))
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        jax.nn.sigmoid(i.astype(jnp.float32)) * x.astype(jnp.float32))
    h = a * h + gated
    return h.astype(x.dtype), h
