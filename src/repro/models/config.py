"""Model configuration — covers all 10 assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    arch: str                      # config id, e.g. 'qwen2.5-3b'
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention / position
    rope_theta: float = 1e6
    qkv_bias: bool = False
    pos: str = "rope"              # rope | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    window: Optional[int] = None   # sliding-window size for local attention

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # dispatch: 'dense' (XLA-lowered scatter), 'a2a' (explicit shard_map
    # all-to-all — the §Perf fix), 'auto' (a2a when a no-FSDP mesh context
    # is active)
    moe_impl: str = "auto"

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    conv_width: int = 4
    ssm_expand: int = 2

    # hybrid (recurrentgemma): layer pattern unit, e.g. ('rec','rec','attn')
    pattern: Tuple[str, ...] = ()
    lru_width: Optional[int] = None

    # encoder-decoder (whisper)
    enc_layers: int = 0
    dec_layers: int = 0

    # misc
    act: str = "swiglu"            # swiglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # attention chunking (flash-style scan blocks)
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 512    # sequence chunking of the xent loss
    # long-context capability: True iff decode state is sub-quadratic in ctx
    subquadratic: bool = False
    # unroll the layer scan (exact XLA cost_analysis for rooflines; scan
    # keeps HLO compact for the pass/fail dry-run)
    unroll_layers: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        from dataclasses import replace
        return replace(self, **kw)

    # Exact parameter counts come from the initialized shapes — see
    # ``api.param_counts(cfg)`` (total and MoE-active).
