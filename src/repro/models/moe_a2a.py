"""Explicit all-to-all MoE dispatch (shard_map) — the §Perf fix for the
collective-bound MoE cells.

Baseline problem (measured, EXPERIMENTS.md §Perf): with tokens sharded on
the batch axes and experts sharded on another axis, XLA lowers the capacity
 -buffer scatter to *replicate-and-all-reduce*: every layer all-reduces the
full (E, C, d) buffer (kimi-k2 prefill: 14.8 TiB/device/step). The classic
fix is the explicit MoE all-to-all:

  per shard: route local tokens into (E, C_l, d) send buckets (local
  scatter), all_to_all over the expert axis -> (E_l, n*C_l, d), run the
  local experts (optionally TP on d_ff with a final psum of the combined
  token outputs), all_to_all back, combine gates locally.

Per-device wire bytes drop from O(E·C·d) all-reduce to O(T_l·k·cf·d)
all-to-all — a ~n_expert_shards x reduction.

Used when a sharding-rules context with a mesh is active and the expert
weights carry no FSDP dim (serving; or training with fsdp=None). Falls back
to the dense formulation otherwise.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .layers import _load_balance_loss


def _axes_tuple(ax) -> Tuple[str, ...]:
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


def moe_ffn_a2a(x, router_w, w1, w3, w2, *, top_k: int,
                capacity_factor: float, dtype, mesh, token_axes,
                expert_axes, tp_axis: Optional[str]):
    """x: (B, S, d) batch-sharded on token_axes; w1/w3: (E, d, f), w2:
    (E, f, d) with E sharded on expert_axes and optionally f on tp_axis."""
    B, S, d = x.shape
    E = router_w.shape[1]
    tok = _axes_tuple(token_axes)
    exp = _axes_tuple(expert_axes)
    n_e = int(np.prod([mesh.shape[a] for a in exp])) if exp else 1
    if n_e == 1 or E % n_e != 0 or (B % n_e != 0 and tok == exp):
        from .layers import moe_ffn
        return moe_ffn(x, router_w, w1, w3, w2, top_k=top_k,
                       capacity_factor=capacity_factor, dtype=dtype)
    f = w1.shape[-1]
    tp = tp_axis if (tp_axis and tp_axis in mesh.axis_names and
                     f % mesh.shape[tp_axis] == 0 and
                     tp_axis not in exp and tp_axis not in tok) else None

    n_tok = int(np.prod([mesh.shape[a] for a in tok])) if tok else 1
    T_l = (B // n_tok if B % n_tok == 0 else B) * S
    C_l = max(1, int(np.ceil(T_l * top_k / E * capacity_factor)))

    def local(xl, rw, w1l, w3l, w2l):
        Bl, Sl, _ = xl.shape
        Tl = Bl * Sl
        xf = xl.reshape(Tl, d)
        logits = xf.astype(jnp.float32) @ rw.astype(jnp.float32)
        gval, gidx = jax.lax.top_k(logits, top_k)
        gates = jax.nn.softmax(gval, axis=-1)

        flat_e = gidx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Tl), top_k)
        order = jnp.argsort(flat_e, stable=True)
        se, stt = flat_e[order], flat_t[order]
        idx = jnp.arange(Tl * top_k)
        first = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
        seg = jax.lax.cummax(jnp.where(first, idx, 0))
        rank = idx - seg
        keep = rank < C_l
        slot = jnp.where(keep, se * C_l + rank, E * C_l)

        send = jnp.zeros((E * C_l, d), dtype).at[slot].set(
            xf[stt].astype(dtype), mode="drop").reshape(E, C_l, d)
        # dispatch: split experts across shards, concat token slices
        recv = send
        for a in exp:
            recv = jax.lax.all_to_all(recv, a, split_axis=0, concat_axis=1,
                                      tiled=True)
        # recv: (E_l, n_e*C_l, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv,
                                   w1l.astype(dtype))) * \
            jnp.einsum("ecd,edf->ecf", recv, w3l.astype(dtype))
        y = jnp.einsum("ecf,efd->ecd", h, w2l.astype(dtype))
        # return path (y is f-partial if TP; combine after token-side sum)
        back = y
        for a in reversed(exp):
            back = jax.lax.all_to_all(back, a, split_axis=1, concat_axis=0,
                                      tiled=True)
        back = back.reshape(E * C_l, d)
        sg = jax.nn.softmax(gval, axis=-1).reshape(-1)[order]
        contrib = jnp.where(keep[:, None],
                            back[jnp.clip(slot, 0, E * C_l - 1)] *
                            sg[:, None].astype(dtype), 0)
        out = jnp.zeros((Tl, d), dtype).at[stt].add(contrib)
        if tp is not None:
            out = jax.lax.psum(out, tp)
        aux = _load_balance_loss(logits, gidx, E)
        aux = jax.lax.pmean(aux, tok) if tok else aux
        return out.reshape(Bl, Sl, d), aux

    batch_ok = B % n_tok == 0 if tok else True
    x_spec = P(tok if batch_ok and tok else None, None, None)
    w_spec = P(exp, None, tp)
    w2_spec = P(exp, tp, None)
    out = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(), w_spec, w_spec, w2_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, router_w, w1, w3, w2)
    return out
