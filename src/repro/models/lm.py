"""LM assembly for all families: dense / vlm / moe / ssm / hybrid / encdec.

Design:
* params are plain dict pytrees; per-layer tensors are stacked on a leading
  L dim and consumed by ``lax.scan`` (compact HLO at any depth — critical for
  512-device SPMD compile times);
* a parallel *logical spec* tree drives the sharding planner;
* three entry modes share block code: 'train' (no cache), 'prefill'
  (build cache), 'decode' (one token against the cache);
* losses are computed with a sequence-chunked cross-entropy so full
  (B, S, V) logits never materialize.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import layers as L
from . import ssm as SSM
from . import rglru as RG
from repro.dist.sharding import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense(key, shp, dt, spec, scale=None):
    return L.dense_init(key, shp, dt, spec, scale)


def init_params(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    """Returns (params, logical_specs) — parallel pytrees."""
    dt = cfg.pdt
    d, f, V, hd = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.kv_heads
    ks = jax.random.split(key, 64)
    kit = iter(ks)

    def attn_block(Lc):
        p = {
            "ln1": (jnp.ones((Lc, d), dt), ("layers", "embed")),
            "wq": _dense(next(kit), (Lc, d, Hq * hd), dt, ("layers", "fsdp", "tp")),
            "wk": _dense(next(kit), (Lc, d, Hkv * hd), dt, ("layers", "fsdp", "kv_tp")),
            "wv": _dense(next(kit), (Lc, d, Hkv * hd), dt, ("layers", "fsdp", "kv_tp")),
            "wo": _dense(next(kit), (Lc, Hq * hd, d), dt, ("layers", "tp", "fsdp")),
        }
        if cfg.qkv_bias:
            p["bq"] = (jnp.zeros((Lc, Hq * hd), dt), ("layers", "tp"))
            p["bk"] = (jnp.zeros((Lc, Hkv * hd), dt), ("layers", "kv_tp"))
            p["bv"] = (jnp.zeros((Lc, Hkv * hd), dt), ("layers", "kv_tp"))
        return p

    def mlp_block(Lc, ff=f):
        if cfg.act == "swiglu":
            return {
                "ln2": (jnp.ones((Lc, d), dt), ("layers", "embed")),
                "w1": _dense(next(kit), (Lc, d, ff), dt, ("layers", "fsdp", "tp")),
                "w3": _dense(next(kit), (Lc, d, ff), dt, ("layers", "fsdp", "tp")),
                "w2": _dense(next(kit), (Lc, ff, d), dt, ("layers", "tp", "fsdp")),
            }
        return {
            "ln2": (jnp.ones((Lc, d), dt), ("layers", "embed")),
            "w1": _dense(next(kit), (Lc, d, ff), dt, ("layers", "fsdp", "tp")),
            "w2": _dense(next(kit), (Lc, ff, d), dt, ("layers", "tp", "fsdp")),
        }

    tree: Dict[str, Any] = {
        "embed": _dense(next(kit), (V, d), dt, ("vocab", "fsdp"), scale=0.02),
        "final_ln": (jnp.ones((d,), dt), ("embed",)),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = _dense(next(kit), (d, V), dt, ("fsdp", "vocab"))

    Lc = cfg.layers
    if cfg.family in ("dense", "vlm"):
        tree["layers"] = {**attn_block(Lc), **mlp_block(Lc)}
    elif cfg.family == "moe":
        E = cfg.n_experts
        tree["layers"] = {
            **attn_block(Lc),
            "ln2": (jnp.ones((Lc, d), dt), ("layers", "embed")),
            "router": _dense(next(kit), (Lc, d, E), jnp.float32,
                             ("layers", "embed", None)),
            "we1": _dense(next(kit), (Lc, E, d, f), dt,
                          ("layers", "experts", "fsdp", "tp")),
            "we3": _dense(next(kit), (Lc, E, d, f), dt,
                          ("layers", "experts", "fsdp", "tp")),
            "we2": _dense(next(kit), (Lc, E, f, d), dt,
                          ("layers", "experts", "tp", "fsdp")),
        }
    elif cfg.family == "ssm":
        din = cfg.ssm_expand * d
        N = cfg.ssm_state
        H = cfg.ssm_heads or (din // cfg.ssm_head_dim)
        conv_dim = din + 2 * N
        dproj = 2 * din + 2 * N + H
        tree["layers"] = {
            "ln": (jnp.ones((Lc, d), dt), ("layers", "embed")),
            "in_proj": _dense(next(kit), (Lc, d, dproj), dt,
                              ("layers", "fsdp", "tp")),
            "conv_w": _dense(next(kit), (Lc, cfg.conv_width, conv_dim), dt,
                             ("layers", None, "tp"), scale=0.5),
            "A_log": (jnp.zeros((Lc, H), jnp.float32), ("layers", "heads")),
            "D": (jnp.ones((Lc, H), jnp.float32), ("layers", "heads")),
            "dt_bias": (jnp.zeros((Lc, H), jnp.float32), ("layers", "heads")),
            "gnorm": (jnp.ones((Lc, din), dt), ("layers", "tp")),
            "out_proj": _dense(next(kit), (Lc, din, d), dt,
                               ("layers", "tp", "fsdp")),
        }
    elif cfg.family == "hybrid":
        unit = len(cfg.pattern)
        groups = cfg.layers // unit
        rest = cfg.layers - groups * unit
        Dr = cfg.lru_width or d
        rec_per_unit = sum(1 for t in cfg.pattern if t == "rec")
        att_per_unit = unit - rec_per_unit

        def rec_block(n):
            return {
                "ln": (jnp.ones((n, d), dt), ("layers", "embed")),
                "wx": _dense(next(kit), (n, d, Dr), dt, ("layers", "fsdp", "tp")),
                "wg": _dense(next(kit), (n, d, Dr), dt, ("layers", "fsdp", "tp")),
                "conv_w": _dense(next(kit), (n, cfg.conv_width, Dr), dt,
                                 ("layers", None, "tp"), scale=0.5),
                "wr": _dense(next(kit), (n, Dr, Dr), dt, ("layers", "tp_in", "tp")),
                "wi": _dense(next(kit), (n, Dr, Dr), dt, ("layers", "tp_in", "tp")),
                "lam": (jnp.full((n, Dr), 0.5, jnp.float32), ("layers", "tp")),
                "wo": _dense(next(kit), (n, Dr, d), dt, ("layers", "tp", "fsdp")),
            }

        tree["groups"] = {
            "rec": {k: (jnp.reshape(v, (groups, rec_per_unit) + v.shape[1:]),
                        ("layers", "unit") + s[1:])
                    for k, (v, s) in rec_block(groups * rec_per_unit).items()},
            "attn": {k: (jnp.reshape(v, (groups, att_per_unit) + v.shape[1:]),
                         ("layers", "unit") + s[1:])
                     for k, (v, s) in attn_block(groups * att_per_unit).items()},
            "mlp": {k: (jnp.reshape(v, (groups, unit) + v.shape[1:]),
                        ("layers", "unit") + s[1:])
                    for k, (v, s) in mlp_block(groups * unit).items()},
        }
        if rest:
            tree["tail"] = {"rec": rec_block(rest),
                            "mlp": mlp_block(rest)}
    elif cfg.family == "encdec":
        tree["enc_layers"] = {**attn_block(cfg.enc_layers),
                              **mlp_block(cfg.enc_layers)}
        dec = attn_block(cfg.dec_layers)
        cross = {f"x{k}": v for k, v in attn_block(cfg.dec_layers).items()}
        tree["dec_layers"] = {**dec, **cross, **mlp_block(cfg.dec_layers)}
        tree["enc_final_ln"] = (jnp.ones((d,), dt), ("embed",))
    else:
        raise ValueError(cfg.family)

    return L.split_tree(tree)


# ---------------------------------------------------------------------------
# blocks (shared across modes)
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, x):
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    B, S = x.shape[:2]
    q = q.reshape(B, S, cfg.n_heads, cfg.hd)
    k = k.reshape(B, S, cfg.kv_heads, cfg.hd)
    v = v.reshape(B, S, cfg.kv_heads, cfg.hd)
    return q, k, v


def _pos_embed(cfg, q, k, pos):
    if cfg.pos == "mrope":
        q = L.apply_mrope(q, pos, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_mrope(k, pos, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.pos == "rope":
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
    return q, k


def attn_apply(cfg, p, x, pos, mode, cache, *, causal=True, window=None):
    """Returns (y, new_cache). cache = (k, v, cache_len) or None."""
    B, S = x.shape[:2]
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, p, h)
    q, k = _pos_embed(cfg, q, k, pos)
    new_cache = None
    if mode == "train":
        o = L.flash_attention(q, k, v, causal=causal, window=window,
                              q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    elif mode == "prefill":
        kc, vc, _ = cache
        if window is not None and kc.shape[1] < S:  # ring cache (local attn)
            W = kc.shape[1]
            tail_k, tail_v = k[:, -W:], v[:, -W:]
            rot = S % W
            tail_k = jnp.roll(tail_k, rot, axis=1)
            tail_v = jnp.roll(tail_v, rot, axis=1)
            kc, vc = tail_k.astype(kc.dtype), tail_v.astype(vc.dtype)
        else:
            kc = jax.lax.dynamic_update_slice(
                kc, k.astype(kc.dtype), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                vc, v.astype(vc.dtype), (0, 0, 0, 0))
        o = L.flash_attention(q, k, v, causal=causal, window=window,
                              q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        new_cache = (kc, vc, jnp.full((B,), S, jnp.int32))
    else:  # decode
        kc, vc, clen = cache
        Smax = kc.shape[1]
        slot = (clen % Smax) if window is not None else clen
        kc = kc.at[jnp.arange(B), slot].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[jnp.arange(B), slot].set(v[:, 0].astype(vc.dtype))
        eff_len = jnp.minimum(clen + 1, Smax) if window is not None else clen + 1
        if window is not None:
            # ring cache: every slot valid once warm; positions are implicit
            o = L.decode_attention(q, kc, vc, eff_len, window=None)
        else:
            o = L.decode_attention(q, kc, vc, clen + 1, window=None)
        new_cache = (kc, vc, clen + 1)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    return (o @ p["wo"]).astype(x.dtype), new_cache


def mlp_apply(cfg, p, x):
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.act == "swiglu":
        return L.swiglu(h, p["w1"], p["w3"], p["w2"]).astype(x.dtype)
    return L.gelu_mlp(h, p["w1"], p["w2"]).astype(x.dtype)


def moe_apply(cfg, p, x):
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    from repro.dist import sharding as _shr
    ctx = _shr._ACTIVE[-1] if _shr._ACTIVE else None
    use_a2a = (cfg.moe_impl == "a2a" or
               (cfg.moe_impl == "auto" and ctx is not None and
                ctx.mesh is not None and ctx.rules.get("fsdp") is None and
                ctx.rules.get("experts")))
    if use_a2a and ctx is not None and ctx.mesh is not None:
        from .moe_a2a import moe_ffn_a2a
        avail = set(ctx.mesh.axis_names)
        tok = tuple(a for a in _as_tuple(ctx.rules.get("batch")) if a in avail)
        exp = tuple(a for a in _as_tuple(ctx.rules.get("experts"))
                    if a in avail)
        tp = ctx.rules.get("tp")
        y, aux = moe_ffn_a2a(h, p["router"], p["we1"], p["we3"], p["we2"],
                             top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor,
                             dtype=cfg.cdt, mesh=ctx.mesh, token_axes=tok,
                             expert_axes=exp,
                             tp_axis=tp if isinstance(tp, str) else None)
    else:
        y, aux = L.moe_ffn(h, p["router"], p["we1"], p["we3"], p["we2"],
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor, dtype=cfg.cdt)
    return y.astype(x.dtype), aux


def _as_tuple(ax):
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


def ssm_apply(cfg, p, x, mode, cache):
    """Mamba2 block. cache = SSMCache or None."""
    B, S, d = x.shape
    din = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = cfg.ssm_heads or (din // cfg.ssm_head_dim)
    P_ = din // H
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_cache = None if cache is None else cache.conv
    conv_out, new_conv = SSM.causal_conv(conv_in, p["conv_w"], conv_cache)
    xs, Bc, Cc = jnp.split(conv_out, [din, din + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(B, S, H, P_)
    if mode == "decode":
        y, h_new = SSM.ssd_decode_step(xh[:, 0], dt[:, 0], A, Bc[:, 0],
                                       Cc[:, 0], p["D"], cache.h)
        y = y[:, None]
        new_cache = SSM.SSMCache(h=h_new, conv=new_conv)
    else:
        y, h_final = SSM.ssd_chunked(xh, dt, A, Bc, Cc, p["D"],
                                     chunk=cfg.ssm_chunk)
        new_cache = SSM.SSMCache(h=h_final, conv=new_conv) \
            if mode == "prefill" else None
    y = y.reshape(B, S, din)
    y = L.rmsnorm(y * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    return (y @ p["out_proj"]).astype(x.dtype), new_cache


def rec_apply(cfg, p, x, mode, cache):
    """RG-LRU recurrent block. cache = (h, conv) or None."""
    B, S, d = x.shape
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    u = h @ p["wx"]
    g = jax.nn.gelu(h @ p["wg"])
    conv_cache = None if cache is None else cache[1]
    u, new_conv = SSM.causal_conv(u, p["conv_w"], conv_cache)
    r = u @ p["wr"]
    i = u @ p["wi"]
    if mode == "decode":
        y, h_new = RG.rglru_step(u[:, 0], r[:, 0], i[:, 0], p["lam"], cache[0])
        y = y[:, None]
        new_cache = (h_new, new_conv)
    else:
        y, h_last = RG.rglru_scan(u, r, i, p["lam"])
        new_cache = (h_last, new_conv) if mode == "prefill" else None
    return ((y * g) @ p["wo"]).astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# model application (all modes)
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens):
    e = params["embed"][tokens]
    return constrain(e.astype(cfg.cdt), "batch", "act_seq", None)


def _unembed(cfg, params, h):
    h = L.rmsnorm(h, params["final_ln"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h @ w.astype(h.dtype)).astype(jnp.float32)


def _layer_scan(cfg, stacked, x, body, cache=None, length=None):
    """Scan ``body`` over stacked per-layer params (+ optional cache).

    With cfg.unroll_layers the scan is a Python loop (identical math, bigger
    HLO) — used by the roofline pass because XLA cost_analysis counts a
    while body only once."""
    if cfg.unroll_layers:
        wrapped = jax.checkpoint(body) if cfg.remat else body
        Lc = jax.tree.leaves(stacked)[0].shape[0]
        ys = []
        for i in range(Lc):
            p = jax.tree.map(lambda a: a[i], stacked)
            c = None if cache is None else jax.tree.map(lambda a: a[i], cache)
            x, nc = wrapped(p, x, c)
            ys.append(nc)
        new_cache = None
        if ys and ys[0] is not None:
            new_cache = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        return x, new_cache

    def step(carry, inp):
        x = carry
        p, c = inp
        y, new_c = body(p, x, c)
        return y, new_c

    wrapped = jax.checkpoint(step) if cfg.remat else step
    xs = (stacked, cache)
    x, new_cache = jax.lax.scan(wrapped, x, xs, length=length)
    return x, new_cache


def forward(cfg: ModelConfig, params, tokens, pos, mode: str, cache=None,
            enc_out=None):
    """Shared trunk -> final hidden states (B, S, d). Returns (h, new_cache)."""
    x = _embed(cfg, params, tokens)

    if cfg.family in ("dense", "vlm", "moe"):
        def body(p, xa, c):
            x, aux_acc = xa
            a, nc = attn_apply(cfg, p, x, pos, mode, c, causal=True,
                               window=cfg.window)
            x = x + a
            x = constrain(x, "batch", "act_seq", None)
            if cfg.family == "moe":
                m, aux = moe_apply(cfg, p, x)
                aux_acc = aux_acc + aux
            else:
                m = mlp_apply(cfg, p, x)
            x = x + m
            return (constrain(x, "batch", "act_seq", None), aux_acc), nc

        (x, aux), new_cache = _layer_scan(
            cfg, params["layers"], (x, jnp.zeros((), jnp.float32)), body,
            cache)
        return x, new_cache, aux / max(cfg.layers, 1)

    if cfg.family == "ssm":
        def body(p, x, c):
            y, nc = ssm_apply(cfg, p, x, mode, c)
            return constrain(x + y, "batch", "act_seq", None), nc

        x, new_cache = _layer_scan(cfg, params["layers"], x, body, cache)
        return x, new_cache, jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        unit = len(cfg.pattern)
        groups = cfg.layers // unit
        g_cache, t_cache = (cache if cache is not None else (None, None))

        def gbody(p, x, c):
            mlp_i = 0
            new_c = []
            ri, ai = 0, 0
            for t in cfg.pattern:
                if t == "rec":
                    pp = {k: v[ri] for k, v in p["rec"].items()}
                    cc = None if c is None else (c[0][0][ri], c[0][1][ri])
                    y, nc = rec_apply(cfg, pp, x, mode, cc)
                    if nc is not None:
                        new_c.append(("rec", ri, nc))
                    ri += 1
                else:
                    pp = {k: v[ai] for k, v in p["attn"].items()}
                    cc = None if c is None else (c[1][0][ai], c[1][1][ai],
                                                 c[1][2][ai])
                    y, nc = attn_apply(cfg, pp, x, pos, mode, cc,
                                       causal=True, window=cfg.window)
                    if nc is not None:
                        new_c.append(("attn", ai, nc))
                    ai += 1
                x = x + y
                mp = {k: v[mlp_i] for k, v in p["mlp"].items()}
                x = x + mlp_apply(cfg, mp, x)
                x = constrain(x, "batch", "act_seq", None)
                mlp_i += 1
            # reassemble cache pytrees
            if c is None:
                return x, None
            rec_h = jnp.stack([nc[2][0] for nc in new_c if nc[0] == "rec"]) \
                if any(nc[0] == "rec" for nc in new_c) else c[0][0]
            rec_cv = jnp.stack([nc[2][1] for nc in new_c if nc[0] == "rec"]) \
                if any(nc[0] == "rec" for nc in new_c) else c[0][1]
            at_k = jnp.stack([nc[2][0] for nc in new_c if nc[0] == "attn"]) \
                if any(nc[0] == "attn" for nc in new_c) else c[1][0]
            at_v = jnp.stack([nc[2][1] for nc in new_c if nc[0] == "attn"]) \
                if any(nc[0] == "attn" for nc in new_c) else c[1][1]
            at_l = jnp.stack([nc[2][2] for nc in new_c if nc[0] == "attn"]) \
                if any(nc[0] == "attn" for nc in new_c) else c[1][2]
            return x, ((rec_h, rec_cv), (at_k, at_v, at_l))

        x, new_g_cache = _layer_scan(cfg, params["groups"], x, gbody, g_cache)

        new_t_cache = None
        if "tail" in params:
            rest = cfg.layers - groups * unit
            new_t = []
            for j in range(rest):
                pp = {k: v[j] for k, v in params["tail"]["rec"].items()}
                cc = None if t_cache is None else (t_cache[0][j], t_cache[1][j])
                y, nc = rec_apply(cfg, pp, x, mode, cc)
                if nc is not None:
                    new_t.append(nc)
                x = x + y
                mp = {k: v[j] for k, v in params["tail"]["mlp"].items()}
                x = x + mlp_apply(cfg, mp, x)
            if new_t:
                new_t_cache = (jnp.stack([t[0] for t in new_t]),
                               jnp.stack([t[1] for t in new_t]))
        cache_out = None
        if mode == "prefill" or (cache is not None):
            cache_out = (new_g_cache, new_t_cache)
        return x, cache_out, jnp.zeros((), jnp.float32)

    if cfg.family == "encdec":
        # tokens = decoder tokens; enc_out = encoder hidden states
        def dec_body(p, x, c):
            self_p = {k: p[k] for k in
                      ("ln1", "wq", "wk", "wv", "wo") if k in p}
            a, nc = attn_apply(cfg, self_p, x, pos, mode, c, causal=True)
            x = x + a
            xp = {k[1:]: p[k] for k in p if k.startswith("x")}
            ca = _cross_attn(cfg, xp, x, enc_out)
            x = x + ca
            x = x + mlp_apply(cfg, p, x)
            return constrain(x, "batch", "act_seq", None), nc

        x, new_cache = _layer_scan(cfg, params["dec_layers"], x, dec_body,
                                   cache)
        return x, new_cache, jnp.zeros((), jnp.float32)

    raise ValueError(cfg.family)


def _cross_attn(cfg, p, x, enc_out):
    B, S = x.shape[:2]
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    k = (enc_out @ p["wk"]).reshape(B, enc_out.shape[1], cfg.kv_heads, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(B, enc_out.shape[1], cfg.kv_heads, cfg.hd)
    o = L.flash_attention(q, k, v, causal=False, q_chunk=cfg.q_chunk,
                          kv_chunk=cfg.kv_chunk)
    return (o.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]).astype(x.dtype)


def encode(cfg: ModelConfig, params, frames):
    """Whisper encoder over stub frame embeddings (B, S, d)."""
    x = frames.astype(cfg.cdt)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(p, x, c):
        a, _ = attn_apply(cfg, p, x, pos, "train", None, causal=False)
        x = x + a
        x = x + mlp_apply(cfg, p, x)
        return constrain(x, "batch", "act_seq", None), None

    x, _ = _layer_scan(cfg, params["enc_layers"], x, body, None)
    return L.rmsnorm(x, params["enc_final_ln"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# losses & serving entry points
# ---------------------------------------------------------------------------

def xent_chunked(cfg, params, h, labels, chunk: int | None = None):
    """Sequence-chunked softmax cross-entropy (never materializes full
    logits). labels: (B, S) int32; -1 = masked."""
    B, S, d = h.shape
    chunk = min(chunk or cfg.loss_chunk, S)
    nc = (S + chunk - 1) // chunk
    pad = nc * chunk - S
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hs = hp.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = lp.reshape(B, nc, chunk).transpose(1, 0, 2)

    def step(carry, inp):
        tot, cnt = carry
        hc, lc = inp
        logits = _unembed(cfg, params, hc)          # (B, chunk, V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((logz - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    step = jax.checkpoint(step) if cfg.remat else step
    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())),
                                 (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def make_positions(cfg, tokens):
    B, S = tokens.shape[:2]
    if cfg.pos == "mrope":
        p = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return jnp.stack([p, p, p])  # text-only default; VLM feeds real grids
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
