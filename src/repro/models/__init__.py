from .config import ModelConfig
from .api import build_model, Model

__all__ = ["ModelConfig", "build_model", "Model"]
