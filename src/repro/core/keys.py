"""Vertex-ID key handling.

IDs live in a universe [0, 2^x). JAX runs without x64, so keys are carried as
(..., 2) uint32 arrays ``[hi, lo]`` (hi = bits 32..63, lo = bits 0..31). All
bit arithmetic is static-shift only — layer fan-outs are compile-time
constants, so extraction lowers to shifts/ands on the VPU.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

__all__ = ["pack_keys", "unpack_keys", "extract_bits", "key_sort_order"]


def pack_keys(ids, key_bits: int) -> jnp.ndarray:
    """Python/numpy ints (or uint32/uint64 array) -> (..., 2) uint32 keys."""
    arr = np.asarray(ids, dtype=np.uint64)
    if key_bits < 64:
        assert int(arr.max(initial=0)) < (1 << key_bits), "ID exceeds universe"
    hi = (arr >> np.uint64(32)).astype(np.uint32)
    lo = (arr & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return jnp.stack([jnp.asarray(hi), jnp.asarray(lo)], axis=-1)


def unpack_keys(keys) -> np.ndarray:
    """(..., 2) uint32 keys -> numpy uint64."""
    k = np.asarray(keys, dtype=np.uint64)
    return (k[..., 0] << np.uint64(32)) | k[..., 1]


def extract_bits(keys: jnp.ndarray, start_lsb: int, width: int) -> jnp.ndarray:
    """Extract ``width`` bits whose least-significant absolute bit index is
    ``start_lsb`` (0 = LSB of the 64-bit value). Returns int32 in [0, 2^width).

    start_lsb/width are static; the three cases below are resolved at trace
    time.
    """
    assert 0 <= width <= 31, "layer fanout bits must fit int32"
    hi, lo = keys[..., 0], keys[..., 1]
    mask = jnp.uint32((1 << width) - 1)
    if width == 0:
        return jnp.zeros(hi.shape, jnp.int32)
    if start_lsb >= 32:
        v = (hi >> jnp.uint32(start_lsb - 32)) & mask
    elif start_lsb + width <= 32:
        v = (lo >> jnp.uint32(start_lsb)) & mask
    else:  # spans the word boundary
        lo_bits = 32 - start_lsb
        low_part = lo >> jnp.uint32(start_lsb)
        high_part = hi & jnp.uint32((1 << (start_lsb + width - 32)) - 1)
        v = (high_part << jnp.uint32(lo_bits)) | low_part
    return v.astype(jnp.int32)


def key_sort_order(keys: jnp.ndarray) -> jnp.ndarray:
    """Stable order sorting keys lexicographically by (hi, lo)."""
    return jnp.lexsort((keys[..., 1], keys[..., 0]))


def layer_bit_offsets(fanout_bits: Sequence[int], key_bits: int):
    """LSB offset of each layer's segment. Layer 0 owns the top ``a_0`` bits
    of the x-bit key. If sum(a) > x (baseline configs), the key is logically
    left-padded with zeros: the root layer simply has dead high branches."""
    total = sum(fanout_bits)
    offs = []
    consumed = 0
    for a in fanout_bits:
        offs.append(total - consumed - a)
        consumed += a
    # Shift so bit 0 of the logical key = bit 0 of the stored key; when
    # total > key_bits the extra high bits read as zero automatically only if
    # they exist in the 64-bit container — enforce total <= 64.
    assert total <= 64, "configuration exceeds 64-bit container"
    return offs
