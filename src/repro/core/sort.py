"""SORT — Space-Optimized Radix Tree, functional JAX implementation.

TPU adaptation of the paper's pointer structure (§3.1, Algorithm 1):

* each layer is a flat **node pool**: an int32 array of ``cap_nodes * 2^{a_i}``
  slots; a "child pointer" is the child's node id in layer ``i+1``'s pool
  (-1 = null). The leaf layer stores vertex-table offsets.
* inserts are **layer-synchronous and batched**: at each layer the whole key
  batch computes its child slot; keys that miss dedup their slots
  (sort + first-occurrence rank) and bump-allocate node ids — the
  deterministic equivalent of the paper's CAS/ROWEX protocol.
* lookups are ``l`` dependent gathers (vectorized over the batch) — this is
  the hot path fused by the ``sort_lookup`` Pallas kernel.

All functions are jit-compatible; ``SortSpec`` is static (hashable).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .keys import extract_bits, layer_bit_offsets
from .sort_optimizer import SortConfig, optimize_sort

__all__ = ["SortSpec", "SortState", "make_sort", "lookup", "insert_mappings",
           "delete_keys", "materialized_slots"]


@dataclass(frozen=True)
class SortSpec:
    """Static structure of a SORT instance."""

    fanout_bits: Tuple[int, ...]
    key_bits: int
    node_caps: Tuple[int, ...]   # max nodes per layer (node_caps[0] == 1)

    @property
    def layers(self) -> int:
        return len(self.fanout_bits)

    @property
    def bit_offsets(self) -> Tuple[int, ...]:
        return tuple(layer_bit_offsets(self.fanout_bits, self.key_bits))

    def pool_sizes(self) -> Tuple[int, ...]:
        return tuple(c << a for c, a in zip(self.node_caps, self.fanout_bits))

    @staticmethod
    def from_config(cfg: SortConfig, n_max: int,
                    capacity_factor: float | None = None) -> "SortSpec":
        """Derive pool capacities. Worst case: each inserted key instantiates
        at most one node per layer, and layer i can hold at most
        2^{s_{i-1}} nodes. ``capacity_factor`` (e.g. 2.0) instead sizes by
        expected occupancy × factor (reported-memory mode; overflow is
        counted, never UB)."""
        caps = [1]
        prefix = 0
        for i in range(1, cfg.layers):
            prefix += cfg.fanout_bits[i - 1]
            hard = 1 << min(prefix, 40)
            cap = min(n_max, hard)
            if capacity_factor is not None:
                from .sort_optimizer import node_probability
                suffix = sum(cfg.fanout_bits[i:])
                exp_nodes = min(n_max, 2 ** max(cfg.key_bits - suffix, 0)) * \
                    node_probability(cfg.key_bits, min(suffix, cfg.key_bits), n_max)
                cap = min(cap, max(64, int(exp_nodes * capacity_factor) + 64))
            caps.append(int(cap))
        return SortSpec(cfg.fanout_bits, cfg.key_bits, tuple(caps))


class SortState(NamedTuple):
    """Dynamic state (a pytree of device arrays)."""

    pools: Tuple[jnp.ndarray, ...]  # int32 per layer
    counts: jnp.ndarray             # int32[l] allocated nodes per layer
    overflow: jnp.ndarray           # int32 scalar — node-pool exhaustion count


def make_sort(spec: SortSpec) -> SortState:
    pools = tuple(jnp.full((s,), -1, jnp.int32) for s in spec.pool_sizes())
    counts = jnp.zeros((spec.layers,), jnp.int32).at[0].set(1)
    return SortState(pools, counts, jnp.zeros((), jnp.int32))


def _child_slots(spec: SortSpec, i: int, node: jnp.ndarray,
                 keys: jnp.ndarray) -> jnp.ndarray:
    idx = extract_bits(keys, spec.bit_offsets[i], spec.fanout_bits[i])
    return node * (1 << spec.fanout_bits[i]) + idx


def lookup(spec: SortSpec, state: SortState, keys: jnp.ndarray) -> jnp.ndarray:
    """Batched retrieval: (B, 2) uint32 keys -> int32 offsets (-1 = absent)."""
    B = keys.shape[0]
    node = jnp.zeros((B,), jnp.int32)
    valid = jnp.ones((B,), bool)
    for i in range(spec.layers):
        slot = _child_slots(spec, i, node, keys)
        child = state.pools[i][jnp.clip(slot, 0, state.pools[i].shape[0] - 1)]
        child = jnp.where(valid, child, -1)
        valid = child >= 0
        node = jnp.maximum(child, 0)
    return jnp.where(valid, node, -1)


def insert_mappings(spec: SortSpec, state: SortState, keys: jnp.ndarray,
                    offsets: jnp.ndarray, mask: jnp.ndarray) -> SortState:
    """Insert key -> offset mappings for entries where ``mask`` is set.

    Duplicate keys within the masked batch MUST carry identical offsets
    (ensured by the vertex table's intra-batch dedup). Existing mappings are
    overwritten (used by vertex re-insertion after deletion).
    """
    B = keys.shape[0]
    node = jnp.zeros((B,), jnp.int32)
    counts = state.counts
    pools = list(state.pools)
    overflow = state.overflow
    active = mask
    for i in range(spec.layers - 1):
        pool = pools[i]
        fan = 1 << spec.fanout_bits[i]
        slot = _child_slots(spec, i, node, keys)
        child = pool[jnp.clip(slot, 0, pool.shape[0] - 1)]
        missing = (child < 0) & active
        # --- dedup missing slots, allocate node ids at layer i+1 ---
        SENT = pool.shape[0]  # out-of-range sentinel
        s = jnp.where(missing, slot, SENT)
        order = jnp.argsort(s)
        ss = s[order]
        prev = jnp.concatenate([jnp.full((1,), -1, ss.dtype), ss[:-1]])
        first = (ss != prev) & (ss < SENT)
        ranks = jnp.cumsum(first.astype(jnp.int32)) - 1
        n_new = jnp.sum(first.astype(jnp.int32))
        base = counts[i + 1]
        cap = spec.node_caps[i + 1]
        fits = base + n_new <= cap
        overflow = overflow + jnp.where(fits, 0, 1)
        new_id = jnp.where(fits & first, base + ranks, -2)
        # scatter new node ids at first-occurrence slots (drop sentinels)
        tgt = jnp.where(first & fits, ss, SENT)
        pool = pool.at[tgt].set(new_id, mode="drop")
        pools[i] = pool
        counts = counts.at[i + 1].set(jnp.where(fits, base + n_new, base))
        child = pool[jnp.clip(slot, 0, pool.shape[0] - 1)]
        active = active & (child >= 0)
        node = jnp.maximum(child, 0)
    # --- leaf layer: store offsets ---
    i = spec.layers - 1
    pool = pools[i]
    slot = _child_slots(spec, i, node, keys)
    tgt = jnp.where(active, slot, pool.shape[0])
    pools[i] = pool.at[tgt].set(offsets, mode="drop")
    return SortState(tuple(pools), counts, overflow)


def delete_keys(spec: SortSpec, state: SortState, keys: jnp.ndarray,
                mask: jnp.ndarray):
    """Clear leaf slots for present keys. Returns (state, offsets, found)."""
    B = keys.shape[0]
    node = jnp.zeros((B,), jnp.int32)
    valid = mask
    slot = jnp.zeros((B,), jnp.int32)
    for i in range(spec.layers):
        slot = _child_slots(spec, i, node, keys)
        child = state.pools[i][jnp.clip(slot, 0, state.pools[i].shape[0] - 1)]
        child = jnp.where(valid, child, -1)
        valid = child >= 0
        if i < spec.layers - 1:
            node = jnp.maximum(child, 0)
        else:
            offsets = child
    leaf = state.pools[-1]
    tgt = jnp.where(valid, slot, leaf.shape[0])
    leaf = leaf.at[tgt].set(-1, mode="drop")
    pools = state.pools[:-1] + (leaf,)
    return SortState(pools, state.counts, state.overflow), offsets, valid


def materialized_slots(spec: SortSpec, state: SortState) -> jnp.ndarray:
    """Pointer slots actually materialized (the paper's space metric):
    sum_i counts[i] * 2^{a_i}."""
    fans = jnp.asarray([1 << a for a in spec.fanout_bits], jnp.int32)
    return jnp.sum(state.counts * fans)
