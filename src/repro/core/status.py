"""Typed status/refusal codes shared across the incremental-analytics
fallback ladder and the durability subsystem.

Historically ``extract_delta`` and the stores' ``analytics_advance``
ladders passed bare strings around ("defrag", "no-warm", ...). ``Reason``
promotes every one of them to an enum member WITHOUT breaking string
consumers: it is a ``str`` subclass whose value is the exact legacy
string, so ``reason == "defrag"``, ``f"shard0:{reason}"`` and JSON
round-trips all keep working while call sites gain an enumerable,
typo-proof vocabulary. The same enum carries the WAL / checkpoint
recovery codes (``repro.storage``), so a recovery report and an advance
refusal speak one language.
"""
from __future__ import annotations

import enum

__all__ = ["Reason", "ADVANCE_FALLBACKS", "DELTA_REFUSALS", "WAL_TAILS"]


class Reason(str, enum.Enum):
    """One vocabulary for "why did the fast path refuse" — epoch-delta
    extraction, warm-advance fallbacks, and WAL/checkpoint recovery."""

    OK = "ok"

    # -- extract_delta refusals (core/epoch_delta.py) --
    DEFRAG = "defrag"                  # rows may have been recycled
    OVERFLOW = "overflow"              # dropped ops in the window
    ROWS_SHRANK = "rows-shrank"        # never expected without defrag
    VERTEX_EVENT = "vertex-event"      # delete/revive hides in-edges

    # -- analytics_advance fallback ladder (api/store.py) --
    NO_WARM = "no-warm"                # no previous result / no advance form
    DELTA_TOO_LARGE = "delta-too-large"
    ABSENT_SOURCE = "absent-source"
    ADVANCE_REFUSED = "advance-refused"
    NO_WARM_PROGRAM = "no-warm-program"   # e.g. fixed-iteration PageRank
    RESTORE_BOUNDARY = "restore-boundary"  # warm handle predates a restore

    # -- registry warm guards (api/registry.py) --
    DELETES = "deletes"
    WEIGHT_INCREASE = "weight-increase"

    # -- WAL tail states (repro.storage.wal) --
    WAL_TORN = "wal-torn"              # mid-record EOF (crash while writing)
    WAL_BAD_MAGIC = "wal-bad-magic"    # framing lost / overwritten bytes
    WAL_BAD_CRC = "wal-bad-crc"        # payload corrupted on disk
    WAL_BAD_HEADER = "wal-bad-header"  # file preamble unreadable
    WAL_DECODE = "wal-decode"          # CRC-valid record, undecodable body

    # -- checkpoint recovery codes (repro.storage.checkpoint) --
    CKPT_MISSING = "ckpt-missing"
    CKPT_BAD_MANIFEST = "ckpt-bad-manifest"
    CKPT_BAD_CRC = "ckpt-bad-crc"
    CKPT_BAD_CHAIN = "ckpt-bad-chain"  # delta whose base is unrecoverable

    # keep f-string / str() behaviour identical to the legacy plain strings
    # (Python 3.11+ would otherwise render the member name)
    __str__ = str.__str__
    __format__ = str.__format__


# The reasons extract_delta itself can return (besides OK).
DELTA_REFUSALS = frozenset({
    Reason.DEFRAG, Reason.OVERFLOW, Reason.ROWS_SHRANK,
    Reason.VERTEX_EVENT,
})

# Every distinct way analytics_advance can fall back to scratch: the
# delta refusals plus the ladder's own checks plus the registry guards.
ADVANCE_FALLBACKS = frozenset(DELTA_REFUSALS | {
    Reason.NO_WARM, Reason.DELTA_TOO_LARGE, Reason.ABSENT_SOURCE,
    Reason.ADVANCE_REFUSED, Reason.NO_WARM_PROGRAM,
    Reason.RESTORE_BOUNDARY, Reason.DELETES, Reason.WEIGHT_INCREASE,
})

# Non-OK states a WAL scan can end in.
WAL_TAILS = frozenset({
    Reason.WAL_TORN, Reason.WAL_BAD_MAGIC, Reason.WAL_BAD_CRC,
    Reason.WAL_BAD_HEADER, Reason.WAL_DECODE,
})
