"""Vertex table (paper §3.1, Fig. 3a) — struct-of-arrays, functional.

Each row is a vertex block: ID, Del_time, Deg, Size, Cap and the edge-array
location. The paper's ``EdgeArr*`` pointer becomes ``start_block`` — the
first block of the vertex's contiguous extent in the global edge pool.

Deleted offsets go to a free ring (the paper's reuse queue); reuse pops via
vectorized indexing — the batched analogue of the paper's CAS pops.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import sort as sort_mod
from .sort import SortSpec, SortState

__all__ = ["VertexTable", "make_vertex_table", "ensure_vertices",
           "delete_vertices", "num_active"]


class VertexTable(NamedTuple):
    ids: jnp.ndarray          # uint32[n_cap, 2] — the vertex ID (hi, lo)
    del_time: jnp.ndarray     # int32[n_cap]: -1 unallocated, 0 active, t>0 deleted@t
    deg: jnp.ndarray          # int32[n_cap] — live degree (as of last compaction)
    size: jnp.ndarray         # int32[n_cap] — occupied entries in edge array
    cap: jnp.ndarray          # int32[n_cap] — edge-array capacity (entries)
    start_block: jnp.ndarray  # int32[n_cap] — extent start block, -1 = none
    num_rows: jnp.ndarray     # int32 scalar — bump high-water mark
    free_q: jnp.ndarray       # int32[n_cap] ring of reusable offsets
    free_head: jnp.ndarray    # int32 scalar (monotonic)
    free_tail: jnp.ndarray    # int32 scalar (monotonic)
    overflow: jnp.ndarray     # int32 scalar — table-full events


def make_vertex_table(n_cap: int) -> VertexTable:
    z = jnp.zeros((), jnp.int32)
    return VertexTable(
        ids=jnp.zeros((n_cap, 2), jnp.uint32),
        del_time=jnp.full((n_cap,), -1, jnp.int32),
        deg=jnp.zeros((n_cap,), jnp.int32),
        size=jnp.zeros((n_cap,), jnp.int32),
        cap=jnp.zeros((n_cap,), jnp.int32),
        start_block=jnp.full((n_cap,), -1, jnp.int32),
        num_rows=z,
        free_q=jnp.zeros((n_cap,), jnp.int32),
        free_head=z,
        free_tail=z,
        overflow=z,
    )


def num_active(vt: VertexTable) -> jnp.ndarray:
    return jnp.sum((vt.del_time == 0).astype(jnp.int32))


def ensure_vertices(spec: SortSpec, st: SortState, vt: VertexTable,
                    keys: jnp.ndarray, mask: jnp.ndarray):
    """Locate-or-insert a batch of vertex IDs.

    Returns (sort_state, vertex_table, offsets[B], created[B]). Duplicate IDs
    within the batch resolve to one shared new offset. Offsets are -1 only on
    table overflow (also counted in vt.overflow).
    """
    B = keys.shape[0]
    n_cap = vt.del_time.shape[0]
    off = sort_mod.lookup(spec, st, keys)
    missing = (off < 0) & mask

    # ---- intra-batch dedup of missing keys (lexicographic sort) ----
    SENT = jnp.uint32(0xFFFFFFFF)
    k_hi = jnp.where(missing, keys[:, 0], SENT)
    k_lo = jnp.where(missing, keys[:, 1], SENT)
    order = jnp.lexsort((k_lo, k_hi))
    sh, sl = k_hi[order], k_lo[order]
    m_sorted = missing[order]
    prev_h = jnp.concatenate([SENT[None], sh[:-1]])
    prev_l = jnp.concatenate([SENT[None], sl[:-1]])
    first = ((sh != prev_h) | (sl != prev_l)) & m_sorted
    group = jnp.cumsum(first.astype(jnp.int32)) - 1          # group id (sorted order)
    n_new = jnp.sum(first.astype(jnp.int32))

    # ---- allocate offsets for group representatives ----
    avail = vt.free_tail - vt.free_head
    j = jnp.arange(B, dtype=jnp.int32)                        # representative rank
    from_queue = j < avail
    q_idx = (vt.free_head + j) % n_cap
    reused = vt.free_q[q_idx]
    bumped = vt.num_rows + (j - jnp.minimum(avail, n_new))
    alloc = jnp.where(from_queue, reused, bumped)             # offset for rank j
    fits = alloc < n_cap
    alloc = jnp.where(fits, alloc, -1)
    n_over = jnp.sum(((j < n_new) & ~fits).astype(jnp.int32))

    # representative rank of each sorted element = group id
    off_sorted = jnp.where(m_sorted, alloc[jnp.clip(group, 0, B - 1)], -1)
    # scatter back to original order
    new_off = jnp.zeros((B,), jnp.int32).at[order].set(off_sorted)
    offsets = jnp.where(missing, new_off, off)
    created = missing & (offsets >= 0)

    # ---- update allocator cursors ----
    used_from_q = jnp.minimum(avail, n_new)
    bump_used = jnp.maximum(n_new - avail, 0) - n_over
    vt = vt._replace(
        free_head=vt.free_head + used_from_q,
        num_rows=vt.num_rows + jnp.maximum(bump_used, 0),
        overflow=vt.overflow + n_over,
    )

    # ---- initialize new rows (one scatter per field; dup groups share off,
    #      identical values so scatter order is immaterial) ----
    tgt = jnp.where(created, offsets, n_cap)
    vt = vt._replace(
        ids=vt.ids.at[tgt].set(keys, mode="drop"),
        del_time=vt.del_time.at[tgt].set(0, mode="drop"),
        deg=vt.deg.at[tgt].set(0, mode="drop"),
        size=vt.size.at[tgt].set(0, mode="drop"),
        cap=vt.cap.at[tgt].set(0, mode="drop"),
        start_block=vt.start_block.at[tgt].set(-1, mode="drop"),
    )
    st = sort_mod.insert_mappings(spec, st, keys, offsets, created)
    return st, vt, offsets, created


def delete_vertices(spec: SortSpec, st: SortState, vt: VertexTable,
                    keys: jnp.ndarray, mask: jnp.ndarray, ts: jnp.ndarray):
    """Mark vertices deleted at timestamp ``ts``.

    The SORT leaf slot is cleared (the ID resolves to absent afterwards).
    The offset is recycled into the free ring only at the next pool
    defragmentation — the epoch-based analogue of the paper's "deleted
    vertices are only purged from the queue when all transactions before
    Del_time are finished": stale edge references to the offset are filtered
    by the del_time check until defrag drops them, so a recycled offset can
    never resurrect old edges. Returns (st, vt, offsets, found)."""
    n_cap = vt.del_time.shape[0]
    st, offsets, found = sort_mod.delete_keys(spec, st, keys, mask)
    # only delete rows that are currently active
    row_ok = found & (vt.del_time[jnp.clip(offsets, 0, n_cap - 1)] == 0)
    tgt = jnp.where(row_ok, offsets, n_cap)
    vt = vt._replace(del_time=vt.del_time.at[tgt].set(ts, mode="drop"))
    return st, vt, offsets, row_ok
