"""RadixGraph — the paper's full structure behind an ID-level API.

Host-side wrapper owning a ``GraphState`` pytree plus jitted, padded-batch
update/read functions. All device work is pure; every mutation returns a new
state, and retained old states are exactly the paper's MVCC versioned arrays.
"""
from __future__ import annotations

import functools
import math
import time
from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import edgepool as ep
from . import sort as sort_mod
from . import vertex_table as vt_mod
from .keys import pack_keys, unpack_keys
from .sort import SortSpec, SortState
from .sort_optimizer import SortConfig, optimize_sort
from .vertex_table import VertexTable

__all__ = ["RadixGraph", "GraphState", "GraphSnapshot", "step_add_vertices",
           "step_delete_vertices", "step_update_edges",
           "step_update_edges_pipelined", "step_lookup",
           "step_degree_counts", "step_neighbors", "step_snapshot",
           "interleave_undirected"]


def interleave_undirected(src, dst, w):
    """Undirected edge-op doubling, shared by every storage backend:
    interleave the two directions so the mixed-op stream order is preserved
    (op i's orientations land at timestamps 2i, 2i+1)."""
    s2 = np.empty(2 * len(src), np.uint64)
    d2 = np.empty_like(s2)
    w2 = np.empty(2 * len(src), np.float32)
    s2[0::2], s2[1::2] = src, dst
    d2[0::2], d2[1::2] = dst, src
    w2[0::2], w2[1::2] = w, w
    return s2, d2, w2


class GraphState(NamedTuple):
    sort: SortState
    vt: VertexTable
    pool: ep.EdgePool


class GraphSnapshot(NamedTuple):
    """CSR view of the live graph (analytics input). Padded to m_cap."""

    indptr: jnp.ndarray   # int32[n_cap + 1]
    dst: jnp.ndarray      # int32[m_cap] destination offsets
    weight: jnp.ndarray   # float32[m_cap]
    n_rows: jnp.ndarray   # int32 — vertex-table high-water mark
    m: jnp.ndarray        # int32 — live edge count
    active: jnp.ndarray   # bool[n_cap] — row is a live vertex
    ids: jnp.ndarray      # uint32[n_cap, 2] — row -> vertex ID


# --------------------------------------------------------------------------
# pure per-shard state transitions
#
# These are the single-shard building blocks: plain functions of
# (static specs, GraphState, batched ops) -> new GraphState. The host
# ``RadixGraph`` wrapper jits them below; ``repro.dist.graph_engine``
# shard_maps/vmaps the very same functions over a stacked shard dim, so the
# single- and multi-shard paths share one implementation.
# --------------------------------------------------------------------------

def step_add_vertices(sspec: SortSpec, pspec: ep.PoolSpec, state: GraphState,
                      keys, mask):
    """Locate-or-insert vertices. Returns (state, offsets, created)."""
    st, vt, off, created = vt_mod.ensure_vertices(sspec, state.sort, state.vt,
                                                  keys, mask)
    return GraphState(st, vt, state.pool), off, created


def step_delete_vertices(sspec: SortSpec, pspec: ep.PoolSpec,
                         state: GraphState, keys, mask):
    """Mark vertices deleted at the current clock. Returns
    (state, offsets, found)."""
    ts = state.pool.clock
    st, vt, off, found = vt_mod.delete_vertices(sspec, state.sort, state.vt,
                                                keys, mask, ts)
    # a vertex delete hides every incident edge (in- AND out-) at read time;
    # in-degrees are not tracked, so the live-edge counter goes stale until
    # the next defrag / host recount resynchronizes it
    any_del = (jnp.sum(found.astype(jnp.int32)) > 0).astype(jnp.int32)
    pool = state.pool._replace(
        clock=state.pool.clock + 1,
        live_dirty=jnp.maximum(state.pool.live_dirty, any_del))
    return GraphState(st, vt, pool), off, found


def step_update_edges(sspec: SortSpec, pspec: ep.PoolSpec, state: GraphState,
                      src_keys, dst_keys, w, mask):
    """Apply a batch of edge ops by vertex KEY (``w == 0`` deletes).

    Returns (state, dropped): ``dropped`` counts masked ops that could not be
    applied — vertex-table exhaustion (either endpoint) or pool exhaustion.
    """
    B = src_keys.shape[0]
    keys = jnp.concatenate([src_keys, dst_keys], axis=0)
    m2 = jnp.concatenate([mask, mask])
    st, vt, off, _ = vt_mod.ensure_vertices(sspec, state.sort, state.vt,
                                            keys, m2)
    u, v = off[:B], off[B:]
    vtx_dropped = jnp.sum((mask & ((u < 0) | (v < 0))).astype(jnp.int32))
    pool, vt, dropped = ep.apply_edge_updates(pspec, state.pool, vt, u, v, w,
                                              mask)
    return GraphState(st, vt, pool), dropped + vtx_dropped


def step_update_edges_pipelined(sspec: SortSpec, pspec: ep.PoolSpec,
                                state: GraphState, src_keys, dst_keys, w,
                                mask):
    """Apply a STACKED (K, B, ...) super-batch of edge ops as one device
    program: a ``lax.scan`` of ``step_update_edges``, so K batches cost a
    single dispatch and the drop counter accumulates on device (one host
    fetch per flush instead of per batch).

    Bit-exact vs K sequential ``step_update_edges`` calls — the scan body IS
    the per-batch transition, overflow-defrag fallback (``lax.cond`` inside
    ``apply_edge_updates``) included, so a mid-super-batch rebuild behaves
    identically. Returns (state, dropped) with scalar summed drops.
    """
    def body(g, xs):
        return step_update_edges(sspec, pspec, g, *xs)

    state, drops = jax.lax.scan(body, state, (src_keys, dst_keys, w, mask))
    return state, jnp.sum(drops, dtype=jnp.int32)


def step_lookup(sspec: SortSpec, pspec: ep.PoolSpec, state: GraphState, keys):
    """Key -> vertex-table offset (-1 absent)."""
    return sort_mod.lookup(sspec, state.sort, keys)


def step_degree_counts(sspec: SortSpec, pspec: ep.PoolSpec, state: GraphState,
                       keys, read_ts=None):
    """Live (deduplicated, tombstone-free) out-degree per query key; 0 for
    absent vertices. The owner-side answer of the distributed 1-hop query."""
    off = sort_mod.lookup(sspec, state.sort, keys)
    _, _, _, cnt = ep.get_neighbors(pspec, state.pool, state.vt, off,
                                    read_ts=read_ts)
    return cnt


def step_neighbors(sspec: SortSpec, pspec: ep.PoolSpec, state: GraphState,
                   keys, width: int, read_ts=None):
    """Fused key->offset lookup + MVCC get-neighbors: one device dispatch per
    padded query batch (no host round-trip between SORT and the pool scan).
    Returns (dst_offsets, weights, ts, counts) with rows front-packed."""
    off = sort_mod.lookup(sspec, state.sort, keys)
    return ep.get_neighbors(pspec, state.pool, state.vt, off,
                            read_ts=read_ts, width=width)


# --------------------------------------------------------------------------
# jitted host-API wrappers (static: sort spec, pool spec)
# --------------------------------------------------------------------------

_add_vertices = jax.jit(step_add_vertices, static_argnums=(0, 1))
_delete_vertices = jax.jit(step_delete_vertices, static_argnums=(0, 1))
_update_edges = jax.jit(step_update_edges, static_argnums=(0, 1))
# steady-state variants donate the input state pytree: XLA reuses the pool /
# vertex-table buffers for the output instead of allocating a second image
# (the pinned-state check in ``_apply_edge_batches`` keeps captured epochs
# and MVCC versions donation-exempt)
_update_edges_donate = jax.jit(step_update_edges, static_argnums=(0, 1),
                               donate_argnums=(2,))
_update_edges_pipe = jax.jit(step_update_edges_pipelined,
                             static_argnums=(0, 1))
_update_edges_pipe_donate = jax.jit(step_update_edges_pipelined,
                                    static_argnums=(0, 1), donate_argnums=(2,))
_lookup = jax.jit(step_lookup, static_argnums=(0, 1))
_neighbors = jax.jit(step_neighbors, static_argnums=(0, 1, 4))


def step_snapshot(sspec: SortSpec, pspec: ep.PoolSpec, m_cap: int,
                  state: GraphState, read_ts=None):
    """Build the CSR ``GraphSnapshot`` of the live (or ``read_ts``-versioned)
    graph. Pure per-shard transition: the host wrapper jits it below and the
    distributed engine shard_maps it per shard (``dist.graph_engine``)."""
    vt = state.vt
    n_cap = vt.size.shape[0]
    so, sd, sw, stv, keep = ep.live_edges(pspec, state.pool, vt,
                                          read_ts=read_ts)
    m = jnp.sum(keep.astype(jnp.int32))
    counts = jnp.zeros((n_cap,), jnp.int32).at[
        jnp.where(keep, so, n_cap)].add(1, mode="drop")
    indptr = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)])
    # entries already sorted by (owner, dst); pack keeps to the front
    kpos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    tgt = jnp.where(keep, kpos, m_cap)
    dst = jnp.full((m_cap,), -1, jnp.int32).at[tgt].set(sd, mode="drop")
    wgt = jnp.zeros((m_cap,), jnp.float32).at[tgt].set(sw, mode="drop")
    active = vt.del_time == 0
    return GraphSnapshot(indptr=indptr, dst=dst, weight=wgt,
                         n_rows=vt.num_rows, m=m, active=active, ids=vt.ids)


_snapshot = jax.jit(step_snapshot, static_argnums=(0, 1, 2))


@functools.partial(jax.jit, static_argnums=(0, 1))
def _defrag(sspec: SortSpec, pspec: ep.PoolSpec, state: GraphState,
            incoming=None):
    pool, vt = ep.defrag(pspec, state.pool, state.vt, incoming)
    return GraphState(state.sort, vt, pool)


# --------------------------------------------------------------------------


@dataclass
class RadixGraph:
    """Dynamic graph store. ``n_max`` vertices / ``pool_blocks`` blocks are
    hard capacities (static shapes); overflow is counted, never UB."""

    n_max: int
    key_bits: int = 32
    expected_n: Optional[int] = None
    layers: Optional[int] = None
    pool_blocks: Optional[int] = None
    block_size: int = 16
    k_max: int = 256
    dmax: int = 4096
    batch: int = 4096          # padded op-batch size
    undirected: bool = False
    probe_width: int = 256     # live-edge probe window (entries per pair)
    k_big: int = 16            # per-batch full-width (dmax) compaction budget
    append_impl: str = "auto"  # 'ref' scatter+window probe | 'pallas' fused
    compact_impl: str = "auto"
    defrag_impl: str = "auto"  # 'stream' block-row rebuild | 'dense' lexsort
    capacity_factor: Optional[float] = None
    policy: str = "snaplog"    # 'snaplog' (paper) | 'grow' | 'sorted' baselines
    buf_blocks: int = 1
    sort_config: Optional[SortConfig] = None  # override the optimizer (baselines)
    pipeline_depth: int = 8    # edge batches staged per flush sync point
    donate_apply: bool = True  # donate the state pytree in steady-state applies
    fuse_scan: bool = False    # fuse each group into ONE lax.scan program

    def __post_init__(self):
        n = self.expected_n or self.n_max
        # paper setting: l = lglg(u) (e.g. 5 for u = 2^32); DP prunes a_i = 0
        l = self.layers or max(2, round(math.log2(max(2, self.key_bits))))
        self.config: SortConfig = self.sort_config or optimize_sort(
            n, self.key_bits, l)
        self.sort_spec = SortSpec.from_config(self.config, self.n_max,
                                              self.capacity_factor)
        nb = self.pool_blocks or max(64, (8 * self.n_max) // self.block_size)
        self.pool_spec = ep.PoolSpec(n_blocks=nb, block_size=self.block_size,
                                     k_max=self.k_max, dmax=self.dmax,
                                     probe_width=self.probe_width,
                                     k_big=self.k_big,
                                     append_impl=self.append_impl,
                                     compact_impl=self.compact_impl,
                                     defrag_impl=self.defrag_impl,
                                     policy=self.policy,
                                     buf_blocks=self.buf_blocks)
        self.state = GraphState(
            sort=sort_mod.make_sort(self.sort_spec),
            vt=vt_mod.make_vertex_table(self.n_max),
            pool=ep.make_edge_pool(self.pool_spec),
        )
        # retained MVCC versions: (label, version_ts, state)
        self._versions: list[tuple[int, int, GraphState]] = []
        self.dropped_ops: int = 0  # masked edge ops refused at capacity
        # epoch-cached CSR snapshots: (read_ts, m_cap) -> (state, snapshot).
        # A hit requires identity with the CURRENT state pytree, so every
        # mutation (which necessarily produces a new functional state)
        # invalidates implicitly; mutators also clear the dict explicitly.
        self._snap_cache: dict = {}
        self._epoch: int = 0          # bumped by every mutating op
        self.snapshot_hits: int = 0
        self.snapshot_misses: int = 0
        # maintenance-spike accounting: wall-clock ms spent in ops that
        # paid a global rebuild — explicit defrags and apply batches that
        # triggered one (the tier-L fallback spikes) — and how many did
        self.defrag_ms: float = 0.0
        # defrag_ms split: host-stage (staging + async dispatch) vs the
        # device-blocked sync tail of the spiking window — separable
        # because every spike window already records its stage/sync split
        self.defrag_host_ms: float = 0.0
        self.defrag_sync_ms: float = 0.0
        self.defrag_batches: int = 0
        self._seen_defrags: int = 0
        # pipelined-apply accounting: a flush is one ``_apply_edge_batches``
        # call (= one host sync point), a super-batch one device dispatch of
        # up to ``pipeline_depth`` fused batches. The freshly-built state is
        # pinned (donation-exempt): its zero-filled leaves can share one
        # device buffer, which XLA refuses to donate twice — jitted outputs
        # thereafter are distinct buffers and donate freely.
        self._pinned: Optional[GraphState] = self.state
        self.pipe_flushes: int = 0
        self.pipe_super_batches: int = 0
        self.pipe_stage_ms: float = 0.0   # host staging + async dispatch
        self.pipe_sync_ms: float = 0.0    # blocked on device at the flush

    # ---- batching helpers ----
    def _pad(self, arr, fill, dtype):
        a = np.asarray(arr)
        B = self.batch
        n = a.shape[0]
        nb = ((n + B - 1) // B) * B if n else B
        out = np.full((nb,) + a.shape[1:], fill, dtype=dtype)
        if n:
            out[:n] = a
        mask = np.zeros((nb,), bool)
        mask[:n] = True
        return out, mask

    def _key_batches(self, ids):
        ids = np.asarray(ids, np.uint64)
        padded, mask = self._pad(ids, 0, np.uint64)
        for i in range(0, padded.shape[0], self.batch):
            yield (pack_keys(padded[i:i + self.batch], self.key_bits),
                   jnp.asarray(mask[i:i + self.batch]))

    def _invalidate(self):
        """Every mutating op seals a new epoch: cached CSR snapshots of the
        previous epoch are dropped (reads on an UNCHANGED graph keep hitting
        the cache and never rescan the pool)."""
        self._epoch += 1
        self._snap_cache.clear()

    # ---- public API ----
    def add_vertices(self, ids):
        self._invalidate()
        offs = []
        for keys, mask in self._key_batches(ids):
            self.state, off, _ = _add_vertices(self.sort_spec, self.pool_spec,
                                               self.state, keys, mask)
            offs.append(np.asarray(off))
        n = len(np.asarray(ids))
        return np.concatenate(offs)[:n] if offs else np.zeros(0, np.int32)

    def delete_vertices(self, ids):
        self._invalidate()
        for keys, mask in self._key_batches(ids):
            self.state, _, _ = _delete_vertices(self.sort_spec, self.pool_spec,
                                                self.state, keys, mask)

    def lookup(self, ids):
        out = []
        n = len(np.asarray(ids))
        for keys, mask in self._key_batches(ids):
            out.append(np.asarray(_lookup(self.sort_spec, self.pool_spec,
                                          self.state, keys)))
        return np.concatenate(out)[:n] if out else np.zeros(0, np.int32)

    def _edge_batches(self, src, dst, w):
        src = np.asarray(src, np.uint64)
        dst = np.asarray(dst, np.uint64)
        w = np.asarray(w, np.float32)
        if self.undirected:
            src, dst, w = interleave_undirected(src, dst, w)
        ps, mask = self._pad(src, 0, np.uint64)
        pd, _ = self._pad(dst, 0, np.uint64)
        pw, _ = self._pad(w, 0, np.float32)
        B = self.batch
        for i in range(0, ps.shape[0], B):
            yield (pack_keys(ps[i:i + B], self.key_bits),
                   pack_keys(pd[i:i + B], self.key_bits),
                   jnp.asarray(pw[i:i + B]), jnp.asarray(mask[i:i + B]))

    def _edge_super_batches(self, src, dst, w):
        """Super-batches of depth <= ``pipeline_depth``: groups of k flat
        (B, ...) batch tuples by default, or ONE stacked (k, B, ...) tuple
        when ``fuse_scan`` is set (the single-program ``lax.scan`` entry).
        The ragged tail ships at its true depth k' < K (jit retraces per
        distinct k): padding with fully-masked batches would still advance
        the pool clock per batch and break parity with sequential applies."""
        src = np.asarray(src, np.uint64)
        dst = np.asarray(dst, np.uint64)
        w = np.asarray(w, np.float32)
        if self.undirected:
            src, dst, w = interleave_undirected(src, dst, w)
        ps, mask = self._pad(src, 0, np.uint64)
        pd, _ = self._pad(dst, 0, np.uint64)
        pw, _ = self._pad(w, 0, np.float32)
        B = self.batch
        NB = ps.shape[0] // B
        K = max(1, int(self.pipeline_depth))
        sk = pack_keys(ps, self.key_bits)       # one packing pass, reshaped
        dk = pack_keys(pd, self.key_bits)       # into (k, B, 2) slices below
        i = 0
        while i < NB:
            k = min(K, NB - i)
            lo, hi = i * B, (i + k) * B
            if k > 1 and self.fuse_scan:
                yield k, (jnp.reshape(sk[lo:hi], (k, B, 2)),
                          jnp.reshape(dk[lo:hi], (k, B, 2)),
                          jnp.asarray(pw[lo:hi].reshape(k, B)),
                          jnp.asarray(mask[lo:hi].reshape(k, B)))
            else:
                yield k, [(sk[a:a + B], dk[a:a + B], jnp.asarray(pw[a:a + B]),
                           jnp.asarray(mask[a:a + B]))
                          for a in range(lo, hi, B)]
            i += k

    def _note_spike(self, t0: float, t1: Optional[float] = None):
        """Attribute the finished op's wall time to the spike accounting
        when it paid a global rebuild (the pool's defrags counter
        advanced past the watermark). ``t1`` is the stage->sync boundary
        of the window; the split lands in ``defrag_host_ms`` /
        ``defrag_sync_ms`` (``t1=None`` books everything as host time)."""
        d = int(self.state.pool.defrags)
        if d != self._seen_defrags:
            now = time.perf_counter()
            self.defrag_ms += (now - t0) * 1000.0
            self.defrag_host_ms += ((t1 if t1 is not None else now) - t0) \
                * 1000.0
            if t1 is not None:
                self.defrag_sync_ms += (now - t1) * 1000.0
            self.defrag_batches += d - self._seen_defrags
            self._seen_defrags = d

    def pin_live_state(self):
        """Exempt the CURRENT state pytree from buffer donation. Called
        whenever an external handle may retain the live arrays (epoch
        capture, MVCC checkpoint): the next apply then runs its first
        dispatch through the non-donating program instead of invalidating
        the retained buffers."""
        self._pinned = self.state

    def _apply_edge_batches(self, src, dst, w):
        self._invalidate()
        t0 = time.perf_counter()
        drops = []
        for k, xs in self._edge_super_batches(src, dst, w):
            if isinstance(xs, list):
                # default steady state: k flat donated dispatches with NO
                # host sync between them. Measured faster than the fused
                # lax.scan program on XLA CPU, where the loop-carried pool
                # scatters lose the in-place-update optimization the flat
                # program gets (~4x per batch at benchmark capacities).
                for x in xs:
                    donate = self.donate_apply and \
                        (self.state is not self._pinned)
                    fn = _update_edges_donate if donate else _update_edges
                    self.state, d = fn(self.sort_spec, self.pool_spec,
                                       self.state, *x)
                    drops.append(d)            # device scalar — no sync here
            else:
                donate = self.donate_apply and (self.state is not self._pinned)
                fn = _update_edges_pipe_donate if donate else _update_edges_pipe
                self.state, d = fn(self.sort_spec, self.pool_spec,
                                   self.state, *xs)
                drops.append(d)
            self.pipe_super_batches += 1
        self.pipe_stage_ms += (time.perf_counter() - t0) * 1000.0
        t1 = time.perf_counter()
        # ONE host sync per flush: fetching the drop counters forces the
        # whole dispatched chain; the defrag watermark delta then attributes
        # any rebuild spike to this flush window
        self.dropped_ops += sum(int(d) for d in drops)
        self.pipe_sync_ms += (time.perf_counter() - t1) * 1000.0
        self.pipe_flushes += 1
        self._note_spike(t0, t1)

    def add_edges(self, src, dst, weight=None):
        w = np.ones(len(np.asarray(src)), np.float32) if weight is None \
            else np.asarray(weight, np.float32)
        assert np.all(w != 0), "weight 0 is the NULL tombstone; use delete_edges"
        self._apply_edge_batches(src, dst, w)

    update_edges = add_edges  # same log-append op (paper: insert == update)

    def delete_edges(self, src, dst):
        w = np.zeros(len(np.asarray(src)), np.float32)  # NULL tombstones
        self._apply_edge_batches(src, dst, w)

    def apply_ops(self, src, dst, weight):
        """Order-preserving mixed stream: weight==0 deletes, else insert/update
        (the paper's mixed-updates workload, Fig. 9)."""
        self._apply_edge_batches(src, dst, np.asarray(weight, np.float32))

    def neighbors(self, ids, width=None, read_ts=None, as_ids=True):
        """Get-neighbors for a batch of vertex IDs (paper: O(d) per vertex).

        The SORT lookup is fused into the jitted read (``step_neighbors``):
        one device dispatch per padded key batch, and the padded batch shape
        keeps the jit cache warm across differently-sized queries."""
        width = width or self.pool_spec.dmax
        n = len(np.asarray(ids))
        ds, ws, cs = [], [], []
        for keys, _ in self._key_batches(ids):
            bd, bw, _, bcnt = _neighbors(self.sort_spec, self.pool_spec,
                                         self.state, keys, width, read_ts)
            ds.append(np.asarray(bd))
            ws.append(np.asarray(bw))
            cs.append(np.asarray(bcnt))
        d = np.concatenate(ds)[:n]
        w = np.concatenate(ws)[:n]
        cnt = np.concatenate(cs)[:n]
        if as_ids:
            # one batched hi/lo gather over the whole (B, width) offset matrix
            # (rows are front-packed, so entries past cnt[i] are -1: clip for
            # the gather, then slice per vertex — never returned)
            ids_np = np.asarray(self.state.vt.ids)
            oc = np.clip(d, 0, ids_np.shape[0] - 1)
            gids = (ids_np[oc, 0].astype(np.uint64) << np.uint64(32)) \
                | ids_np[oc, 1].astype(np.uint64)
            return [(gids[i, :cnt[i]], w[i, :cnt[i]])
                    for i in range(d.shape[0])]
        return [(d[i, :cnt[i]], w[i, :cnt[i]]) for i in range(d.shape[0])]

    def snapshot(self, read_ts=None, m_cap=None) -> GraphSnapshot:
        """Epoch-cached CSR view: repeated snapshots of an unchanged graph
        return the SAME artifact without rescanning the pool; any mutation
        invalidates (``snapshot_hits``/``snapshot_misses`` expose the
        behaviour for tests and the serving layer)."""
        m_cap = m_cap or self.pool_spec.capacity_entries
        key = (None if read_ts is None else int(read_ts), m_cap)
        hit = self._snap_cache.get(key)
        if hit is not None and hit[0] is self.state:
            self.snapshot_hits += 1
            return hit[1]
        self.snapshot_misses += 1
        snap = _snapshot(self.sort_spec, self.pool_spec, m_cap, self.state,
                         read_ts)
        self._snap_cache[key] = (self.state, snap)
        return snap

    def snapshot_at(self, ts: int, m_cap=None) -> GraphSnapshot:
        """Historical CSR snapshot at operation timestamp ``ts``, resolved
        against retained MVCC versions: the answering state is the EARLIEST
        retained version whose version_ts >= ts (compactions after a
        checkpoint may have dropped pre-checkpoint history from newer
        states), falling back to the live state when ``ts`` is newer than
        every checkpoint."""
        if ts >= self.current_ts:
            return self.snapshot(m_cap=m_cap)
        cands = [v for v in self._versions if v[1] >= ts]
        state = min(cands, key=lambda v: v[1])[2] if cands else self.state
        if state is self.state:
            return self.snapshot(read_ts=ts, m_cap=m_cap)
        m_cap = m_cap or self.pool_spec.capacity_entries
        return _snapshot(self.sort_spec, self.pool_spec, m_cap, state, ts)

    @property
    def current_ts(self) -> int:
        """Timestamp of the latest applied operation (clock points one past)."""
        return int(self.state.pool.clock) - 1

    def checkpoint_version(self, label: Optional[int] = None):
        """Retain the current immutable state (MVCC versioned arrays).
        Returns the version timestamp: reads at read_ts=this see exactly the
        current contents."""
        ts = self.current_ts
        self.pin_live_state()       # retained version must never be donated
        self._versions.append((label if label is not None else ts, ts,
                               self.state))
        return ts

    def retain_version(self, state: GraphState, label: int):
        """Retain an ARBITRARY captured state (not necessarily the live
        one) as an MVCC version — the epoch-chain pin: a warm analytics
        entry keeps its epoch's arrays reachable and time-travel-readable
        until ``release_version(label)``. The version timestamp is the
        captured state's own clock."""
        ts = int(state.pool.clock) - 1
        if state is self.state:
            self.pin_live_state()   # retained version must never be donated
        self._versions.append((label, ts, state))
        return ts

    def release_version(self, label: int) -> int:
        """Drop retained MVCC versions with the given label (as returned by /
        passed to ``checkpoint_version``) so their device arrays can be
        freed instead of leaking for the life of the process. Returns the
        number of versions released."""
        kept = [v for v in self._versions if v[0] != label]
        released = len(self._versions) - len(kept)
        self._versions = kept
        return released

    @property
    def retained_versions(self) -> list:
        """(label, version_ts) of every retained MVCC version."""
        return [(lbl, ts) for lbl, ts, _ in self._versions]

    def defrag(self, pending_src=None):
        """Explicit global rebuild. ``pending_src`` optionally names the
        SOURCE vertex IDs of a batch about to be applied (e.g. one that
        just reported drops): the rebuilt extents are pre-sized for those
        pending ops — ``cap >= size + incoming`` per vertex — so freshly
        rebuilt hub extents don't immediately re-overflow into another
        rebuild when the batch is retried."""
        self._invalidate()
        incoming = None
        if pending_src is not None:
            offs = self.lookup(np.asarray(pending_src, np.uint64))
            incoming = jnp.zeros((self.n_max,), jnp.int32).at[
                jnp.asarray(np.where(offs >= 0, offs, self.n_max))].add(
                    1, mode="drop")
        t0 = time.perf_counter()
        self.state = _defrag(self.sort_spec, self.pool_spec, self.state,
                             incoming)
        t1 = time.perf_counter()
        jax.block_until_ready(self.state.pool.dst)
        self._note_spike(t0, t1)

    # ---- introspection ----
    @property
    def num_vertices(self) -> int:
        return int(vt_mod.num_active(self.state.vt))

    @property
    def num_edges(self) -> int:
        """Live edge count from the incrementally-maintained counter — O(1),
        no CSR rebuild. Vertex deletes / capacity drops mark the counter
        dirty; the recount then reuses the (cached) snapshot and writes the
        exact value back."""
        pool = self.state.pool
        if int(pool.live_dirty):
            snap = self.snapshot()
            m = int(snap.m)
            self.state = GraphState(self.state.sort, self.state.vt,
                                    pool._replace(
                                        live_m=jnp.asarray(m, jnp.int32),
                                        live_dirty=jnp.zeros((), jnp.int32)))
            # re-key the cache entry onto the patched (semantically
            # identical) state so the writeback doesn't evict it
            m_cap = self.pool_spec.capacity_entries
            self._snap_cache[(None, m_cap)] = (self.state, snap)
            # the host-side _replace shares device buffers with the
            # pre-patch state (which callers may still hold) — pin so the
            # next apply never donates them
            self.pin_live_state()
            return m
        return int(pool.live_m)

    @property
    def num_defrags(self) -> int:
        """Global pool rebuilds so far — the fast path's fallback counter
        (hub-heavy streams overflowing more than ``k_big`` over-window
        vertices per batch land here; Theorem 2 keeps it O(log) in the op
        count otherwise)."""
        return int(self.state.pool.defrags)

    @property
    def tiles_scanned(self) -> int:
        """Cumulative pool tiles the bounded append visited (touched owner
        extents + landed slots per batch) — certifies the prefetched scan
        bound: it grows with the batches' footprints, never with
        batches x pool size."""
        return int(self.state.pool.tiles_scanned)

    def memory_bytes(self, materialized=True) -> int:
        """Paper-comparable memory: materialized SORT slots (4B), vertex rows
        (32B as in Fig. 3), occupied edge blocks (12B/entry: dst+weight+ts).
        materialized=False reports full static pool allocation instead."""
        if materialized:
            sort_b = int(sort_mod.materialized_slots(self.sort_spec,
                                                     self.state.sort)) * 4
            vrows = int(self.state.vt.num_rows) * 32
            blocks = int(jnp.sum((self.state.pool.owner >= 0).astype(jnp.int32)))
            return sort_b + vrows + blocks * self.pool_spec.block_size * 12
        sort_b = sum(self.sort_spec.pool_sizes()) * 4
        vrows = self.n_max * 32
        return sort_b + vrows + self.pool_spec.capacity_entries * 12

    @property
    def overflowed(self) -> bool:
        return bool(int(self.state.sort.overflow) or int(self.state.vt.overflow)
                    or int(self.state.pool.overflow))
