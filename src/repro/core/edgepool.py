"""Snapshot-log edge storage (paper §3.3) — functional, pool-based.

TPU adaptation: per-vertex ``malloc``'d edge arrays become contiguous block
**extents** inside one global pool, so

* append = vectorized scatter at ``start_block*BS + size + rank`` (the batched
  analogue of the paper's lock-free ``fetch_add`` slot claim),
* the snapshot/log split is positional: entries [0, deg) are the snapshot,
  [deg, size) the log; capacity keeps the paper's ``cap = 2·snapshot``
  discipline so compaction stays amortized O(1) per op (Theorem 2),
* compaction (Alg. 2) runs batched over up to K_MAX overflowing vertices with
  the duplicate-checker kernel; larger events fall through to a global
  **defragmentation** — a fully-vectorized rebuild (sort + cumsum re-layout)
  that doubles as the allocator's garbage collector. Bump allocation between
  defrags replaces free lists (TPUs want bulk re-layout, not pointer reuse).
* every entry carries a timestamp; reads at ``read_ts`` give MVCC snapshot
  semantics (paper §3.3 "Version management" — old functional states are the
  versioned arrays).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .vertex_table import VertexTable
from repro.kernels import ops as kops

__all__ = ["EdgePool", "PoolSpec", "make_edge_pool", "apply_edge_updates",
           "get_neighbors", "live_edges", "defrag"]

INT_MAX = jnp.int32(0x7FFFFFFF)


@dataclass(frozen=True)
class PoolSpec:
    n_blocks: int          # total blocks in the pool
    block_size: int = 16   # entries per block (lane-friendly)
    k_max: int = 256       # max per-batch vertex compactions (fast path)
    dmax: int = 4096       # max edge-array entries handled by the fast path
    # live-edge probe window (ingest fast path): the pre-append pair-liveness
    # probe gathers at most ``probe_width`` entries per DISTINCT touched pair
    # instead of a dense (B, dmax) slab; vertices whose arrays outgrow the
    # window flag the counter dirty unless this batch's compaction already
    # touched them (their liveness folds out of the compaction gather free).
    probe_width: int = 256
    # two-tier fast-path compaction: up to ``k_max`` overflowing vertices
    # whose arrays fit the probe window compact at window width (the common
    # allocation/growth case), and up to ``k_big`` wider ones (≤ dmax) pay
    # the full-width gather — so per-batch compaction cost tracks the small
    # tier, not k_max × dmax. A batch overflowing MORE than k_big big
    # vertices falls back to a defrag (correct, amortized by the 2x capacity
    # growth: a given vertex overflows O(log d) times total); hub-heavy
    # streams that hit this repeatedly should raise k_big — each unit costs
    # one extra dmax-width compaction row per batch.
    k_big: int = 16
    append_impl: str = "auto"   # 'ref' (jnp scatter) | 'pallas' fused kernel
    compact_impl: str = "auto"
    # global-rebuild strategy: 'stream' (default) runs the block-row
    # streaming rebuild — size-segmented per-vertex row compaction
    # (kernels defrag_rows) + whole-block extent writes, falling back to
    # the dense entry-scatter rebuild whenever a size segment overflows
    # its static budget or a vertex outgrew dmax; 'dense' forces the old
    # full-pool lexsort rebuild (the bit-exact reference, kept for the
    # parity property tests and before/after benchmarks).
    defrag_impl: str = "auto"   # 'auto'/'stream' | 'dense'
    # edge-storage policy (baseline paradigms on the same substrate):
    #  'snaplog' — the paper: dedup compaction, log segment = snapshot size
    #  'grow'    — log-structured (LiveGraph/GTX-style): no dedup, double cap
    #  'sorted'  — Spruce-style: dedup + sort by dst, fixed small buffer
    policy: str = "snaplog"
    buf_blocks: int = 1    # 'sorted' policy: log buffer size (blocks)

    @property
    def capacity_entries(self) -> int:
        return self.n_blocks * self.block_size


class EdgePool(NamedTuple):
    dst: jnp.ndarray       # int32[n_blocks, BS] destination OFFSETS (edge chain); -1 empty
    weight: jnp.ndarray    # float32[n_blocks, BS]; 0.0 = NULL tombstone
    ts: jnp.ndarray        # int32[n_blocks, BS]
    owner: jnp.ndarray     # int32[n_blocks] owning vertex offset, -1 free
    next_block: jnp.ndarray  # int32 scalar bump allocator
    garbage: jnp.ndarray   # int32 scalar — stale entries since last defrag
    clock: jnp.ndarray     # int32 scalar — global timestamp
    overflow: jnp.ndarray  # int32 scalar — pool-exhaustion events
    live_m: jnp.ndarray    # int32 scalar — live (deduped, tombstone-free) edges
    live_dirty: jnp.ndarray  # int32 scalar — 1 when live_m needs a recount
    defrags: jnp.ndarray   # int32 scalar — global rebuilds so far (hub-heavy
    #                        streams exceeding k_big per batch show up here)
    tiles_scanned: jnp.ndarray  # int32 scalar — cumulative pool tiles the
    #                        bounded append visits (touched extents + landed
    #                        slots per batch, NOT tiles x batches: the
    #                        counter certifies the prefetched scan bound)


def make_edge_pool(spec: PoolSpec) -> EdgePool:
    nb, bs = spec.n_blocks, spec.block_size
    z = jnp.zeros((), jnp.int32)
    return EdgePool(
        dst=jnp.full((nb, bs), -1, jnp.int32),
        weight=jnp.zeros((nb, bs), jnp.float32),
        ts=jnp.zeros((nb, bs), jnp.int32),
        owner=jnp.full((nb,), -1, jnp.int32),
        next_block=z, garbage=z, clock=jnp.ones((), jnp.int32), overflow=z,
        live_m=z, live_dirty=z, defrags=z, tiles_scanned=z,
    )


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _cdiv(a, b):
    return (a + b - 1) // b


def _group_by(u: jnp.ndarray, valid: jnp.ndarray):
    """Stable-sort ops by target vertex. Returns dict with the sorted view."""
    B = u.shape[0]
    key = jnp.where(valid, u, INT_MAX)
    order = jnp.argsort(key, stable=True)
    su = key[order]
    prev = jnp.concatenate([jnp.full((1,), -1, su.dtype), su[:-1]])
    first = (su != prev) & (su < INT_MAX)
    gid = jnp.cumsum(first.astype(jnp.int32)) - 1  # group index per sorted op
    # start index of each op's group:
    idx = jnp.arange(B, dtype=jnp.int32)
    start_of_group = jnp.where(first, idx, 0)
    start = jax.lax.cummax(start_of_group)
    rank = idx - start
    # per-group info at group slots (positions 0..ng-1):
    gstart = jnp.nonzero(first, size=B, fill_value=B)[0].astype(jnp.int32)
    gu = su[jnp.clip(gstart, 0, B - 1)]
    nxt = jnp.concatenate([gstart[1:], jnp.full((1,), B, jnp.int32)])
    # count = next group start - start, but next fill is B and invalid groups
    # must count 0:
    ng = jnp.sum(first.astype(jnp.int32))
    garange = jnp.arange(B, dtype=jnp.int32)
    gvalid = garange < ng
    # total valid ops:
    nvalid = jnp.sum(valid.astype(jnp.int32))
    gend = jnp.where(garange + 1 < ng, nxt, nvalid)
    gcount = jnp.where(gvalid, gend - gstart, 0)
    return dict(order=order, su=su, gid=gid, rank=rank, gstart=gstart, gu=gu,
                gcount=gcount, gvalid=gvalid, ng=ng)


def _gather_vertex_entries(spec: PoolSpec, pool: EdgePool, vt: VertexTable,
                           u: jnp.ndarray, width: int):
    """Gather up to ``width`` occupied entries of each vertex in ``u``.

    Returns (dst, w, ts) of shape (K, width) plus validity handled via size.
    """
    K = u.shape[0]
    bs = spec.block_size
    uc = jnp.clip(u, 0, vt.size.shape[0] - 1)
    start = vt.start_block[uc]
    size = jnp.where(u >= 0, vt.size[uc], 0)
    e = jnp.arange(width, dtype=jnp.int32)[None, :]
    blk = start[:, None] + e // bs
    lane = e % bs
    ok = (e < size[:, None]) & (start[:, None] >= 0)
    blk = jnp.where(ok, blk, 0)
    d = jnp.where(ok, pool.dst[blk, lane], -1)
    w = jnp.where(ok, pool.weight[blk, lane], 0.0)
    t = jnp.where(ok, pool.ts[blk, lane], 0)
    return d, w, t, size


def _scatter_entries(pool: EdgePool, tgt_block, lane, valid, d, w, t,
                     owner_of_block=None):
    nb = pool.dst.shape[0]
    tb = jnp.where(valid, tgt_block, nb)
    pool = pool._replace(
        dst=pool.dst.at[tb, lane].set(d, mode="drop"),
        weight=pool.weight.at[tb, lane].set(w, mode="drop"),
        ts=pool.ts.at[tb, lane].set(t, mode="drop"),
    )
    return pool


# --------------------------------------------------------------------------
# first-touch extent allocation (fast path, whole batch)
# --------------------------------------------------------------------------

def _alloc_extents(spec: PoolSpec, pool: EdgePool, vt: VertexTable,
                   ku: jnp.ndarray, kmask: jnp.ndarray,
                   kincoming: jnp.ndarray):
    """Assign fresh extents to vertices with NO edge array yet (the mass
    first-touch case of every ingest stream). There is nothing to gather or
    dedup — the whole batch's allocations are laid out with one cumsum and
    initialized by a flat block-row scatter whose budget is proportional to
    the BATCH (Σ blocks ≤ B/bs + Σ base_log), so thousands of new vertices
    per batch never spill into the compaction tiers or force a defrag."""
    bs = spec.block_size
    K = ku.shape[0]
    nb = pool.dst.shape[0]
    n_cap = vt.size.shape[0]
    base_log = spec.buf_blocks if spec.policy == "sorted" else 1

    new_blocks = jnp.where(kmask,
                           jnp.maximum(_cdiv(kincoming, bs), base_log), 0)
    total = jnp.sum(new_blocks)
    base = pool.next_block + jnp.cumsum(new_blocks) - new_blocks

    # flat row -> owning vertex mapping (interval search over the layout);
    # Σ new_blocks ≤ Σ(cdiv + base_log) ≤ K·(base_log+1) + B/bs, and the
    # budget doubles as a belt-and-braces overflow guard
    R_total = K * (base_log + 1) + _cdiv(K, bs)
    fits = (pool.next_block + total <= nb) & (total <= R_total)
    kmask = kmask & fits
    r = jnp.arange(R_total, dtype=jnp.int32)
    ends = jnp.cumsum(new_blocks)
    krow = jnp.searchsorted(ends, r, side="right").astype(jnp.int32)
    krc = jnp.clip(krow, 0, K - 1)
    valid_r = (r < total) & fits
    tgt_rows = jnp.where(valid_r, pool.next_block + r, nb)
    pool = _scatter_block_rows(pool, tgt_rows,
                               jnp.full((R_total, bs), -1, jnp.int32),
                               jnp.zeros((R_total, bs), jnp.float32),
                               jnp.zeros((R_total, bs), jnp.int32))
    owner = pool.owner.at[tgt_rows].set(jnp.where(valid_r, ku[krc], -1),
                                        mode="drop")

    tgt = jnp.where(kmask, ku, n_cap)
    vt = vt._replace(
        cap=vt.cap.at[tgt].set(new_blocks * bs, mode="drop"),
        start_block=vt.start_block.at[tgt].set(
            jnp.where(new_blocks > 0, base, -1), mode="drop"),
    )
    pool = pool._replace(owner=owner,
                         next_block=pool.next_block +
                         jnp.where(fits, total, 0),
                         overflow=pool.overflow + jnp.where(fits, 0, 1))
    return pool, vt


# --------------------------------------------------------------------------
# per-vertex compaction (fast path) — paper Alg. 2 batched over K_MAX vertices
# --------------------------------------------------------------------------

def _fold_words(n_cap: int) -> int:
    return (n_cap + 31) // 32


def _scatter_block_rows(pool: EdgePool, tgt_rows, d_rows, w_rows, t_rows):
    """Write whole (bs,)-entry block rows: compaction targets are contiguous
    block-aligned extents, so one row-scatter replaces bs entry-scatters."""
    return pool._replace(
        dst=pool.dst.at[tgt_rows].set(d_rows, mode="drop"),
        weight=pool.weight.at[tgt_rows].set(w_rows, mode="drop"),
        ts=pool.ts.at[tgt_rows].set(t_rows, mode="drop"),
    )


def _compact_vertices(spec: PoolSpec, pool: EdgePool, vt: VertexTable,
                      ku: jnp.ndarray, kmask: jnp.ndarray,
                      kincoming: jnp.ndarray, width: int, fold: bool):
    """Compact + grow the edge arrays of vertices ``ku`` (masked), each with
    at most ``width`` occupied entries.

    New capacity (entries) = snapB + max(snapB, incomingB, 1) blocks where
    snapB = blocks(d') — the paper's "new array of capacity 2d, reserving d
    log entries", generalized so the pending batch always fits.

    Returns (pool, vt, fold_ku, fold_bitmap). With ``fold=True`` the deduped
    live set of each compacted vertex — already materialized by the (K,
    width) compaction gather — is returned as a per-vertex bitmap over the
    destination universe, so the live-edge probe stays exact for pairs whose
    owner outgrew the bounded probe window but was compacted this batch.
    ('grow' keeps duplicates and tombstones, so its fold is never valid.)
    """
    bs = spec.block_size
    K = ku.shape[0]
    n_cap = vt.size.shape[0]
    nb = pool.dst.shape[0]

    d0, w0, t0, size0 = _gather_vertex_entries(spec, pool, vt,
                                               jnp.where(kmask, ku, -1),
                                               width)
    if spec.policy == "grow":
        # log-structured baseline: copy everything, no dedup (reads pay O(log))
        cd, cw, ct, cnt = d0, w0, t0, size0
    else:
        cd, cw, ct, cnt = kops.compact_rows(d0, w0, t0, size0,
                                            impl=spec.compact_impl)
        if spec.policy == "sorted":
            # Spruce-style: snapshot kept sorted by destination
            D = cd.shape[1]
            pos = jnp.arange(D, dtype=jnp.int32)[None, :]
            skey = jnp.where(pos < cnt[:, None], cd, INT_MAX)
            o = jnp.argsort(skey, axis=-1, stable=True)
            cd = jnp.take_along_axis(cd, o, -1)
            cw = jnp.take_along_axis(cw, o, -1)
            ct = jnp.take_along_axis(ct, o, -1)
    cnt = jnp.where(kmask, cnt, 0)

    snap_blocks = _cdiv(cnt, bs)
    if spec.policy == "sorted":
        log_blocks = jnp.full_like(snap_blocks, spec.buf_blocks)
    else:  # 'snaplog' (paper: log = snapshot) and 'grow' (double capacity)
        log_blocks = jnp.maximum(jnp.maximum(snap_blocks, _cdiv(kincoming, bs)), 1)
    log_blocks = jnp.maximum(log_blocks, _cdiv(kincoming, bs))
    new_blocks = jnp.where(kmask, snap_blocks + log_blocks, 0)

    base = pool.next_block + jnp.cumsum(new_blocks) - new_blocks
    total = jnp.sum(new_blocks)
    fits = pool.next_block + total <= nb  # caller guarantees via defrag check
    kmask = kmask & fits

    # per-vertex liveness bitmap over dst offsets (fold for the live probe);
    # after dedup each dst appears once per row, so distinct bits per word
    # make scatter-add equivalent to scatter-OR
    Ww = _fold_words(n_cap)
    if fold and spec.policy != "grow":
        ee = jnp.arange(width, dtype=jnp.int32)[None, :]
        entry_ok = kmask[:, None] & (ee < cnt[:, None]) & (cd >= 0)
        cdc = jnp.clip(cd, 0, n_cap - 1)
        krow = jnp.broadcast_to(jnp.arange(K, dtype=jnp.int32)[:, None],
                                (K, width))
        word = jnp.where(entry_ok, cdc >> 5, Ww)
        bit = jnp.where(entry_ok,
                        jnp.uint32(1) << (cdc & 31).astype(jnp.uint32),
                        jnp.uint32(0))
        fold_bitmap = jnp.zeros((K, Ww), jnp.uint32).at[
            krow.reshape(-1), word.reshape(-1)].add(bit.reshape(-1),
                                                    mode="drop")
        fold_ku = jnp.where(kmask, ku, -1)
    else:
        fold_bitmap = jnp.zeros((K, Ww), jnp.uint32)
        fold_ku = jnp.full((K,), -1, jnp.int32)

    # ---- write the new extents as whole BLOCK ROWS (extents are block-
    # aligned, so a row scatter replaces bs entry scatters): content rows
    # carry the compacted prefix padded with empties, then pure-empty log
    # rows out to the extent end. ``MB`` bounds any extent this call can
    # build: snapB <= R1 rows, logB <= max(blocks(dmax), buf_blocks) rows
    # (the caller defrags instead when a vertex's incoming exceeds dmax).
    R1 = _cdiv(width, bs)
    padw = R1 * bs - width
    if padw:
        cd = jnp.pad(cd, ((0, 0), (0, padw)), constant_values=-1)
        cw = jnp.pad(cw, ((0, 0), (0, padw)))
        ct = jnp.pad(ct, ((0, 0), (0, padw)))
    e = jnp.arange(R1 * bs, dtype=jnp.int32)[None, :]
    fillm = e < cnt[:, None]
    rowi = jnp.arange(R1, dtype=jnp.int32)[None, :]
    row_ok = kmask[:, None] & (rowi < new_blocks[:, None])
    pool = _scatter_block_rows(
        pool, jnp.where(row_ok, base[:, None] + rowi, nb).reshape(-1),
        jnp.where(fillm, cd, -1).reshape(K * R1, bs),
        jnp.where(fillm, cw, 0.0).reshape(K * R1, bs),
        jnp.where(fillm, ct, 0).reshape(K * R1, bs))

    MB = R1 + max(_cdiv(spec.dmax, bs), spec.buf_blocks) + 1
    T2 = MB - R1
    rowi2 = jnp.arange(R1, MB, dtype=jnp.int32)[None, :]
    row_ok2 = kmask[:, None] & (rowi2 < new_blocks[:, None])
    pool = _scatter_block_rows(
        pool, jnp.where(row_ok2, base[:, None] + rowi2, nb).reshape(-1),
        jnp.full((K * T2, bs), -1, jnp.int32),
        jnp.zeros((K * T2, bs), jnp.float32),
        jnp.zeros((K * T2, bs), jnp.int32))
    cap_entries = new_blocks * bs

    # ownership: new extents -> u ; old extents -> -1 (garbage)
    b = jnp.arange(MB, dtype=jnp.int32)[None, :]
    new_ob = jnp.where(kmask[:, None] & (b < new_blocks[:, None]),
                       base[:, None] + b, nb)
    ucast = jnp.broadcast_to(ku[:, None], (K, MB))
    owner = pool.owner.at[new_ob.reshape(-1)].set(ucast.reshape(-1), mode="drop")
    uc = jnp.clip(ku, 0, n_cap - 1)
    old_start = jnp.where(kmask, vt.start_block[uc], -1)
    old_blocks = jnp.where(kmask & (old_start >= 0), _cdiv(vt.cap[uc], bs), 0)
    old_ob = jnp.where(kmask[:, None] & (b < old_blocks[:, None]),
                       old_start[:, None] + b, nb)
    owner = owner.at[old_ob.reshape(-1)].set(-1, mode="drop")

    garbage = pool.garbage + jnp.sum(jnp.where(kmask, vt.size[uc], 0) - cnt)
    pool = pool._replace(owner=owner,
                         next_block=pool.next_block + jnp.where(fits, total, 0),
                         garbage=garbage,
                         overflow=pool.overflow + jnp.where(fits, 0, 1))

    # vertex table bookkeeping
    tgt = jnp.where(kmask, ku, n_cap)
    vt = vt._replace(
        deg=vt.deg.at[tgt].set(cnt, mode="drop"),
        size=vt.size.at[tgt].set(cnt, mode="drop"),
        cap=vt.cap.at[tgt].set(cap_entries, mode="drop"),
        start_block=vt.start_block.at[tgt].set(jnp.where(new_blocks > 0, base,
                                                         -1), mode="drop"),
    )
    return pool, vt, fold_ku, fold_bitmap


# --------------------------------------------------------------------------
# global defragmentation — streaming block-row rebuild, GC, vertex-offset
# recycling (dense entry-scatter rebuild kept as the bit-exact reference)
# --------------------------------------------------------------------------

def _rebuild_layout(spec: PoolSpec, vt: VertexTable, d_cnt: jnp.ndarray,
                    incoming: jnp.ndarray):
    """New extent layout of a rebuild: each live vertex with content (or
    pending ``incoming`` ops) gets ``cap = snapB + max(snapB, incomingB,
    1)`` blocks (2d discipline), laid out in vertex-row order."""
    bs = spec.block_size
    snapB = _cdiv(d_cnt, bs)
    has_any = (d_cnt > 0) | (incoming > 0)
    active_row = vt.del_time == 0
    if spec.policy == "sorted":
        base_logB = jnp.full_like(snapB, spec.buf_blocks)
    else:
        base_logB = jnp.maximum(snapB, 1)
    logB = jnp.where(active_row & has_any,
                     jnp.maximum(base_logB, _cdiv(incoming, bs)), 0)
    blocks = jnp.where(active_row, snapB + logB, 0)
    bstart = jnp.cumsum(blocks) - blocks
    return blocks, bstart, jnp.sum(blocks), active_row


def _rebuild_finalize(spec: PoolSpec, pool: EdgePool, vt: VertexTable,
                      new_dst, new_w, new_t, d_cnt, blocks, bstart,
                      total_blocks, live_cnt, active_row):
    """Shared rebuild tail: block ownership via interval mapping, deleted
    vertex rows recycled into the free ring (the paper's epoch-safe purge —
    offsets are only reused after the rebuild, so stale extent references
    cannot resurrect), vertex table + pool bookkeeping. The rebuild is the
    live counter's resynchronization point: ``live_m`` becomes exact and
    any dirtiness (vertex deletes, dropped ops) is healed here."""
    bs = spec.block_size
    nb = pool.dst.shape[0]
    n_cap = vt.size.shape[0]

    bidx = jnp.arange(nb, dtype=jnp.int32)
    vown = jnp.searchsorted(bstart + blocks, bidx, side="right").astype(jnp.int32)
    vownc = jnp.clip(vown, 0, n_cap - 1)
    inside = (bidx < total_blocks) & (bidx >= bstart[vownc]) & (blocks[vownc] > 0)
    new_owner = jnp.where(inside, vownc, -1)

    deleted = vt.del_time > 0
    del_idx = jnp.nonzero(deleted, size=n_cap, fill_value=n_cap)[0].astype(jnp.int32)
    n_del = jnp.sum(deleted.astype(jnp.int32))
    r = jnp.arange(n_cap, dtype=jnp.int32)
    q_pos = (vt.free_tail + r) % n_cap
    q_tgt = jnp.where(r < n_del, q_pos, n_cap)
    free_q = vt.free_q.at[q_tgt].set(del_idx, mode="drop")
    dtgt = jnp.where(deleted, r, n_cap)
    del_time = vt.del_time.at[dtgt].set(-1, mode="drop")

    vt = vt._replace(
        deg=jnp.where(active_row, d_cnt, 0),
        size=jnp.where(active_row, d_cnt, 0),
        cap=jnp.where(active_row, blocks * bs, 0),
        start_block=jnp.where(active_row & (blocks > 0), bstart, -1),
        free_q=free_q,
        free_tail=vt.free_tail + n_del,
        del_time=del_time,
    )
    pool = pool._replace(dst=new_dst, weight=new_w, ts=new_t, owner=new_owner,
                         next_block=total_blocks,
                         garbage=jnp.zeros((), jnp.int32),
                         live_m=live_cnt,
                         live_dirty=jnp.zeros((), jnp.int32),
                         defrags=pool.defrags + 1)
    return pool, vt


def _defrag_dense(spec: PoolSpec, pool: EdgePool, vt: VertexTable,
                  incoming: jnp.ndarray):
    """Dense rebuild reference: flatten every pool lane, one full-pool
    3-key lexsort, entry-level scatters. O(N log N) in the pool CAPACITY —
    the streaming rebuild below is the production path; this stays as the
    bit-exact semantic reference and the fallback for states the size
    segments cannot express (a vertex past dmax, segment overflow)."""
    bs = spec.block_size
    nb = pool.dst.shape[0]
    n_cap = vt.size.shape[0]
    N = nb * bs

    own = jnp.repeat(pool.owner, bs)
    d = pool.dst.reshape(-1)
    w = pool.weight.reshape(-1)
    t = pool.ts.reshape(-1)
    # entry liveness: within owner's occupied prefix
    blk_index = jnp.arange(N, dtype=jnp.int32) // bs
    lane = jnp.arange(N, dtype=jnp.int32) % bs
    ownc = jnp.clip(own, 0, n_cap - 1)
    start = vt.start_block[ownc]
    pos_in_extent = (blk_index - start) * bs + lane
    occupied = (own >= 0) & (pos_in_extent >= 0) & (pos_in_extent < vt.size[ownc])
    src_alive = vt.del_time[ownc] == 0
    dstc = jnp.clip(d, 0, n_cap - 1)
    dst_alive = (d >= 0) & (vt.del_time[dstc] == 0)
    valid = occupied & src_alive & dst_alive & (d >= 0)

    # ---- last-writer-wins on (owner, dst) by ts ----
    SENT = INT_MAX
    so = jnp.where(valid, own, SENT)
    sd = jnp.where(valid, d, SENT)
    stv = jnp.where(valid, t, 0)
    order = jnp.lexsort((stv, sd, so))
    so, sd, sw, stv = so[order], sd[order], w[order], stv[order]
    sval = so < SENT
    nxt_o = jnp.concatenate([so[1:], jnp.full((1,), -2, so.dtype)])
    nxt_d = jnp.concatenate([sd[1:], jnp.full((1,), -2, sd.dtype)])
    is_last = ((so != nxt_o) | (sd != nxt_d)) & sval
    # live pairs after the rebuild (exact, policy-independent): the defrag is
    # the counter's resynchronization point — ``live_m`` becomes exact and any
    # dirtiness (vertex deletes, dropped ops) is healed here
    live_cnt = jnp.sum((is_last & (sw != 0)).astype(jnp.int32))
    if spec.policy == "grow":
        keep = sval  # log-structured baseline: retain every version
    else:
        keep = is_last & (sw != 0)

    # ---- per-vertex live counts & new extents ----
    so_keep = jnp.where(keep, so, n_cap)
    d_cnt = jnp.zeros((n_cap,), jnp.int32).at[so_keep].add(1, mode="drop")
    blocks, bstart, total_blocks, active_row = _rebuild_layout(
        spec, vt, d_cnt, incoming)

    # ---- write entries into fresh arrays ----
    # rank of each kept entry within its owner = position among keeps with
    # same owner; entries are sorted by owner so rank = idx - first_keep_idx
    # rank via segmented cumsum of keep:
    keep_i = keep.astype(jnp.int32)
    csum = jnp.cumsum(keep_i)
    owner_change = jnp.concatenate([jnp.ones((1,), bool), so[1:] != so[:-1]])
    seg_base = jax.lax.cummax(jnp.where(owner_change, csum - keep_i, 0))
    rank = csum - 1 - seg_base

    soc = jnp.clip(so, 0, n_cap - 1)
    entry_pos = bstart[soc] * bs + rank
    tgt_blk = jnp.where(keep, entry_pos // bs, nb)
    tgt_lane = entry_pos % bs

    new_dst = jnp.full((nb, bs), -1, jnp.int32).at[tgt_blk, tgt_lane].set(
        sd, mode="drop")
    new_w = jnp.zeros((nb, bs), jnp.float32).at[tgt_blk, tgt_lane].set(
        sw, mode="drop")
    new_t = jnp.zeros((nb, bs), jnp.int32).at[tgt_blk, tgt_lane].set(
        stv, mode="drop")

    return _rebuild_finalize(spec, pool, vt, new_dst, new_w, new_t, d_cnt,
                             blocks, bstart, total_blocks, live_cnt,
                             active_row)


def _defrag_tiers(spec: PoolSpec, n_cap: int):
    """Static (width, budget) size segments of the streaming rebuild:
    widths grow 8x from one block up to dmax; budgets shrink 8x from the
    full vertex table (heavy-tailed degree distributions put almost every
    vertex in the first segment), floored so hub-heavy states — up to
    4*k_big over-window vertices — still stream. A segment whose live
    population exceeds its budget falls back to the dense rebuild, so the
    budgets trade streaming coverage for bounded gather shapes."""
    bs = spec.block_size
    top = max(_cdiv(spec.dmax, bs) * bs, bs)
    tiers = []
    w, j = bs, 0
    while True:
        w = min(w, top)
        tiers.append((w, min(n_cap, max(64, 4 * spec.k_big,
                                        n_cap >> (3 * j)))))
        if w >= top:
            break
        w, j = w * 8, j + 1
    return tiers


def _defrag_chunks(width: int, budget: int):
    """Geometric chunk schedule of one size segment: (start, rows) pieces
    doubling from ~64K gathered entries, so a segment costs O(population)
    work at runtime — each chunk is skipped by a ``lax.cond`` unless the
    segment's population reaches its start."""
    c = max(32, min(budget, 65536 // max(width, 1)))
    chunks, lo = [], 0
    while lo < budget:
        c = min(c, budget - lo)
        chunks.append((lo, c))
        lo += c
        c *= 2
    return chunks


def _defrag_stream(spec: PoolSpec, pool: EdgePool, vt: VertexTable,
                   incoming: jnp.ndarray, tiers, tier_masks):
    """Block-row streaming rebuild: per size segment, gather each live
    vertex's extent once, run the ``defrag_rows`` row compactor (dedup +
    tombstone/dead-dst drop + dst-ascending emission), and write the new
    extents as whole block rows into a fresh pool image. Segments are
    processed in geometrically-growing chunks, each behind a ``lax.cond``
    on the segment's population, so runtime work is proportional to the
    extents that actually exist (within 2x), never the static budgets or
    the pool capacity — and nothing is ever sorted across vertices: the
    extent layout already IS the owner order. Bit-exact vs
    ``_defrag_dense`` (asserted by the parity property test)."""
    bs = spec.block_size
    nb = pool.dst.shape[0]
    n_cap = vt.size.shape[0]
    keep_all = spec.policy == "grow"
    dead_dst = vt.del_time != 0

    d_cnt = jnp.zeros((n_cap,), jnp.int32)
    live_cnt = jnp.zeros((), jnp.int32)
    parts = []
    for (W, Bj), mask in zip(tiers, tier_masks):
        pop = jnp.sum(mask.astype(jnp.int32))
        kidx = jnp.nonzero(mask, size=Bj, fill_value=n_cap)[0].astype(
            jnp.int32)
        for lo, C in _defrag_chunks(W, Bj):
            kidx_c = jax.lax.slice(kidx, (lo,), (lo + C,))

            def compact_chunk(carry, kidx_c=kidx_c, W=W):
                d_cnt, live_cnt = carry
                kmask = kidx_c < n_cap
                ku = jnp.where(kmask, kidx_c, -1)
                d0, w0, t0, ksz = _gather_vertex_entries(spec, pool, vt,
                                                         ku, W)
                # edges to deleted vertices drop like the dense rebuild
                dd = jnp.where((d0 >= 0) &
                               dead_dst[jnp.clip(d0, 0, n_cap - 1)],
                               -1, d0)
                cd, cw, ct, cnt, liv = kops.defrag_rows(
                    dd, w0, t0, ksz, keep_all=keep_all, n_cap=n_cap,
                    impl=spec.compact_impl)
                cnt = jnp.where(kmask, cnt, 0)
                d_cnt = d_cnt.at[jnp.where(kmask, ku, n_cap)].set(
                    cnt, mode="drop")
                live_cnt = live_cnt + jnp.sum(jnp.where(kmask, liv, 0))
                return (d_cnt, live_cnt), (ku, cd, cw, ct, cnt)

            def skip_chunk(carry, C=C, W=W):
                return carry, (jnp.full((C,), -1, jnp.int32),
                               jnp.full((C, W), -1, jnp.int32),
                               jnp.zeros((C, W), jnp.float32),
                               jnp.zeros((C, W), jnp.int32),
                               jnp.zeros((C,), jnp.int32))

            run = pop > lo
            (d_cnt, live_cnt), part = jax.lax.cond(
                run, compact_chunk, skip_chunk, (d_cnt, live_cnt))
            parts.append((run, W, part))

    blocks, bstart, total_blocks, active_row = _rebuild_layout(
        spec, vt, d_cnt, incoming)

    # fresh image: only content rows are ever written (block-row moves
    # bounded by the live snapshot); log rows stay at the empty fill
    img = pool._replace(dst=jnp.full((nb, bs), -1, jnp.int32),
                        weight=jnp.zeros((nb, bs), jnp.float32),
                        ts=jnp.zeros((nb, bs), jnp.int32))
    for run, W, (ku, cd, cw, ct, cnt) in parts:
        R = W // bs
        K = ku.shape[0]

        def write_chunk(im, ku=ku, cd=cd, cw=cw, ct=ct, cnt=cnt, R=R, K=K):
            base = bstart[jnp.clip(ku, 0, n_cap - 1)]
            rowi = jnp.arange(R, dtype=jnp.int32)[None, :]
            row_ok = (ku >= 0)[:, None] & (rowi < _cdiv(cnt, bs)[:, None])
            return _scatter_block_rows(
                im, jnp.where(row_ok, base[:, None] + rowi, nb).reshape(-1),
                cd.reshape(K * R, bs), cw.reshape(K * R, bs),
                ct.reshape(K * R, bs))

        img = jax.lax.cond(run, write_chunk, lambda im: im, img)

    return _rebuild_finalize(spec, pool, vt, img.dst, img.weight, img.ts,
                             d_cnt, blocks, bstart, total_blocks, live_cnt,
                             active_row)


def defrag(spec: PoolSpec, pool: EdgePool, vt: VertexTable,
           incoming: jnp.ndarray | None = None):
    """Rebuild the pool compactly in vertex order (CSR-like layout).

    * last-writer-wins on (owner, dst) by timestamp, tombstones dropped;
    * edges from/to deleted vertices dropped;
    * deleted vertex rows recycled into the free ring;
    * each live vertex gets ``cap = snapB + max(snapB, incomingB, 1)``
      blocks (2d discipline, pre-sized for ``incoming`` pending ops).

    Dispatch: the streaming block-row rebuild handles every state whose
    live extents fit the size segments (sizes <= dmax, segment counts
    within budget); anything else — and ``defrag_impl='dense'`` — runs
    the dense entry-scatter reference. Both produce identical states.
    """
    n_cap = vt.size.shape[0]
    if incoming is None:
        incoming = jnp.zeros((n_cap,), jnp.int32)
    if spec.defrag_impl == "dense":
        return _defrag_dense(spec, pool, vt, incoming)
    tiers = _defrag_tiers(spec, n_cap)
    live_row = (vt.del_time == 0) & (vt.start_block >= 0)
    sz = jnp.where(live_row, vt.size, 0)
    masks, fits = [], []
    prev = 0
    for W, Bj in tiers:
        m = live_row & (sz > prev) & (sz <= W)
        masks.append(m)
        fits.append(jnp.sum(m.astype(jnp.int32)) <= Bj)
        prev = W
    stream_ok = jnp.all(jnp.stack(fits)) & (jnp.max(sz) <= tiers[-1][0])
    return jax.lax.cond(
        stream_ok,
        lambda args: _defrag_stream(spec, args[0], args[1], incoming,
                                    tiers, masks),
        lambda args: _defrag_dense(spec, args[0], args[1], incoming),
        (pool, vt))


# --------------------------------------------------------------------------
# batched edge updates (insert / update / delete): the paper's O(1) append
# --------------------------------------------------------------------------

def apply_edge_updates(spec: PoolSpec, pool: EdgePool, vt: VertexTable,
                       u: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
                       mask: jnp.ndarray):
    """Apply a batch of edge operations given vertex OFFSETS.

    ``w == 0`` is a deletion (paper: NULL weight log). Ops are timestamped
    ``clock + batch_index`` — the deterministic analogue of the paper's
    per-log fetch_add ordering. Returns (pool, vt, dropped) where ``dropped``
    is the number of masked ops that could not be applied (pool exhaustion);
    the distributed engine reports it per shard.
    """
    B = u.shape[0]
    bs = spec.block_size
    nb = pool.dst.shape[0]
    n_cap = vt.size.shape[0]
    valid = mask & (u >= 0) & (v >= 0)
    ts = pool.clock + jnp.arange(B, dtype=jnp.int32)

    g = _group_by(u, valid)
    guc = jnp.clip(g["gu"], 0, n_cap - 1)
    gsize = jnp.where(g["gvalid"], vt.size[guc], 0)
    gcap = jnp.where(g["gvalid"], vt.cap[guc], 0)
    need = gsize + g["gcount"]
    govf = g["gvalid"] & (need > gcap)

    # fast-path eligibility: whole current array fits the compaction buffer
    # (a vertex whose per-batch incoming exceeds dmax defrags instead so the
    # fast path's static extent bound always holds)
    small_ok = govf & (gcap <= spec.dmax) & (gsize <= spec.dmax) & \
        (g["gcount"] <= spec.dmax)
    n_ovf = jnp.sum(govf.astype(jnp.int32))
    n_small = jnp.sum(small_ok.astype(jnp.int32))
    jumbo = n_ovf != n_small

    # tiered fast path: first-touch vertices (no edge array at all — the
    # bulk of any ingest stream) take the whole-batch allocation tier;
    # in-window arrays compact at window width under the wide k_max budget;
    # the rare big vertex pays the full dmax-width gather under the narrow
    # k_big budget and hands the probe its liveness fold for free.
    tier_a = small_ok & (gsize == 0) & (gcap == 0)
    rest = small_ok & ~tier_a
    dS = min(spec.probe_width, spec.dmax)
    two_tier = dS < spec.dmax
    tier_l = rest & (gsize > dS) if two_tier else jnp.zeros_like(rest)
    tier_s = rest & ~tier_l

    kuA = jnp.where(tier_a, g["gu"], -1)
    kincA = jnp.where(tier_a, g["gcount"], 0)
    base_log = spec.buf_blocks if spec.policy == "sorted" else 1
    worstA = jnp.sum(jnp.where(tier_a, jnp.maximum(_cdiv(kincA, bs),
                                                   base_log), 0))

    def _tier(mask, k_budget):
        kidx = jnp.nonzero(mask, size=k_budget, fill_value=B)[0]
        kmask = kidx < B
        kc = jnp.clip(kidx, 0, B - 1)
        ku = jnp.where(kmask, g["gu"][kc], -1)
        kinc = jnp.where(kmask, g["gcount"][kc], 0)
        truncated = jnp.sum(mask.astype(jnp.int32)) > k_budget
        # upper bound on blocks this tier may allocate:
        worst = jnp.sum(jnp.where(kmask,
                                  _cdiv(jnp.minimum(gsize[kc], spec.dmax),
                                        bs) * 2 + _cdiv(kinc, bs) + 2, 0))
        return ku, kmask, kinc, truncated, worst

    kuS, kmS, kincS, truncS, worstS = _tier(tier_s, spec.k_max)
    kuL, kmL, kincL, truncL, worstL = _tier(tier_l, spec.k_big)
    truncated = truncS | truncL
    pool_tight = pool.next_block + worstA + worstS + worstL > nb
    half_garbage = pool.garbage > (nb * bs) // 2
    do_defrag = jumbo | truncated | pool_tight | half_garbage

    incoming_vec = jnp.zeros((n_cap,), jnp.int32).at[
        jnp.where(g["gvalid"], g["gu"], n_cap)].add(g["gcount"], mode="drop")

    KF = spec.k_big
    Ww = _fold_words(n_cap)

    def _defrag_path(args):
        pool, vt = args
        pool, vt = defrag(spec, pool, vt, incoming_vec)
        # defrag resynchronizes live_m exactly but rebuilds EVERY vertex, so
        # there is no per-vertex fold to hand the probe (over-window vertices
        # in a defrag batch flag dirty instead)
        return (pool, vt, jnp.full((KF,), -1, jnp.int32),
                jnp.zeros((KF, Ww), jnp.uint32))

    def _fast_path(args):
        pool, vt = args
        live = ~do_defrag
        pool, vt = _alloc_extents(spec, pool, vt, kuA, tier_a & live, kincA)
        pool, vt, _, _ = _compact_vertices(spec, pool, vt, kuS, kmS & live,
                                           kincS, dS, fold=False)
        if not two_tier:
            return (pool, vt, jnp.full((KF,), -1, jnp.int32),
                    jnp.zeros((KF, Ww), jnp.uint32))
        pool, vt, fku, fbm = _compact_vertices(spec, pool, vt, kuL,
                                               kmL & live, kincL, spec.dmax,
                                               fold=True)
        return pool, vt, fku, fbm

    pool, vt, fold_ku, fold_bitmap = jax.lax.cond(
        do_defrag, _defrag_path, _fast_path, (pool, vt))

    # ---- append every op at size + rank (log append, O(1) per op) ----
    order = g["order"]
    su = g["su"]
    suc = jnp.clip(su, 0, n_cap - 1)
    base = jnp.where(su < INT_MAX, vt.size[suc], 0)
    slot = base + g["rank"]
    cap_now = jnp.where(su < INT_MAX, vt.cap[suc], 0)
    start = vt.start_block[suc]
    op_ok = (su < INT_MAX) & (slot < cap_now) & (start >= 0)
    dropped = jnp.sum(((su < INT_MAX) & ~op_ok).astype(jnp.int32))

    # ---- incremental live-edge accounting (probed BEFORE the appends land):
    # a distinct (u, v) pair's post-batch liveness is decided by its LAST op;
    # its pre-batch liveness is probed against u's current entries (last-
    # writer-wins by timestamp — the same rule the snapshot applies), so
    #   delta = Σ_pairs applied(last op) · [(w_last != 0) − was_live]
    # keeps ``live_m`` exact without ever rebuilding a CSR. Probe sources, in
    # order of preference:
    #   1. the compaction FOLD — vertices compacted this batch already paid a
    #      (K, dmax) gather, whose deduped live set is returned as a bitmap,
    #      so their pairs are exact at any degree;
    #   2. a bounded-width window (``probe_width`` ≪ dmax) over the owner's
    #      entries — exact while the array fits the window; an over-window
    #      un-folded vertex could hide the pair's newest entry, so it flags
    #      the counter dirty instead of silently drifting.
    # Drops also make the counter unreliable (an earlier op of the pair may
    # have landed): dirty, resynchronized by the next defrag / host recount.
    op_ok_orig = jnp.zeros((B,), bool).at[order].set(op_ok)
    pu = jnp.where(valid, u, INT_MAX)
    pv = jnp.where(valid, v, INT_MAX)
    porder = jnp.lexsort((ts, pv, pu))   # (u, v, ts): last-per-pair = max ts
    u2, v2, w2 = pu[porder], pv[porder], w[porder]
    ok2 = op_ok_orig[porder]
    nu = jnp.concatenate([u2[1:], jnp.full((1,), -2, u2.dtype)])
    nv = jnp.concatenate([v2[1:], jnp.full((1,), -2, v2.dtype)])
    pair_last = ((u2 != nu) | (v2 != nv)) & (u2 < INT_MAX)

    u2c = jnp.clip(u2, 0, n_cap - 1)
    v2c = jnp.clip(v2, 0, n_cap - 1)
    k_of = jnp.full((n_cap + 1,), -1, jnp.int32).at[
        jnp.where(fold_ku >= 0, fold_ku, n_cap)].set(
            jnp.arange(KF, dtype=jnp.int32), mode="drop")[:n_cap]
    krow = jnp.where(pair_last, k_of[u2c], -1)
    fold_hit = krow >= 0
    fw = fold_bitmap[jnp.clip(krow, 0, KF - 1), v2c >> 5]
    fold_live = ((fw >> (v2c & 31).astype(jnp.uint32)) & 1) == 1

    sv = v[order]
    sw_ = w[order]
    sts = ts[order]
    tgt_blk = jnp.where(op_ok, start + slot // bs, nb)

    probe_u = jnp.where(pair_last & ~fold_hit, u2, -1)
    p_start = jnp.where(probe_u >= 0, vt.start_block[u2c], -1)
    p_sz = jnp.where(probe_u >= 0, vt.size[u2c], 0)
    p_v = jnp.where(probe_u >= 0, v2, -1)

    # ---- touched-tile bound: the pool tiles any probe extent or landed
    # slot of this batch can live in. The Pallas append only VISITS these
    # (prefetched tile list; the grid's tail revisits the last touched
    # tile as a no-op), and ``tiles_scanned`` records the bound on both
    # paths — probe extents are marked as [first, last] tile RANGES via a
    # diff/cumsum cover, so even a post-jumbo extent wider than dmax stays
    # fully covered.
    T = kops.append_tile_rows(nb)
    n_tiles = nb // T
    p_rows = _cdiv(p_sz, bs)
    has_p = (p_start >= 0) & (p_rows > 0)
    t_first = jnp.where(has_p, p_start // T, n_tiles)
    t_end = jnp.where(has_p, (p_start + p_rows - 1) // T + 1, n_tiles)
    diff = jnp.zeros((n_tiles + 1,), jnp.int32).at[t_first].add(
        1, mode="drop").at[t_end].add(-1, mode="drop")
    touched = jnp.cumsum(diff[:n_tiles]) > 0
    wmark = jnp.zeros((n_tiles + 1,), bool).at[
        jnp.where(op_ok, tgt_blk // T, n_tiles)].set(True, mode="drop")
    touched = touched | wmark[:n_tiles]
    n_touched = jnp.sum(touched.astype(jnp.int32))
    t_order = jnp.nonzero(touched, size=n_tiles,
                          fill_value=0)[0].astype(jnp.int32)
    t_pad = t_order[jnp.clip(n_touched - 1, 0, n_tiles - 1)]
    tiles_list = jnp.where(jnp.arange(n_tiles, dtype=jnp.int32) < n_touched,
                           t_order, t_pad)

    use_pallas = spec.append_impl == "pallas" or (
        spec.append_impl == "auto" and kops.default_impl() == "pallas")
    if use_pallas:
        # fused append: slot scatter + full-extent last-writer probe in one
        # VMEM-resident pass per TOUCHED pool tile — exact liveness, never
        # blind, and never a full-pool scan
        nd, nw, nt, win_was_live = kops.append_edges(
            pool.dst, pool.weight, pool.ts, tgt_blk, slot % bs, op_ok,
            sv, sw_, sts, p_start, p_sz, p_v, tiles=tiles_list,
            n_touched=n_touched)
        pool = pool._replace(dst=nd, weight=nw, ts=nt)
        probe_blind = jnp.zeros((), bool)
    else:
        Wp = min(spec.probe_width, spec.dmax)
        d_e, w_e, t_e, _ = _gather_vertex_entries(spec, pool, vt,
                                                  probe_u, Wp)
        t_match = jnp.where(d_e == v2[:, None], t_e, 0)  # clock starts at 1
        newest = jnp.argmax(t_match, axis=1)
        win_was_live = (jnp.max(t_match, axis=1) > 0) & \
            (w_e[jnp.arange(B), newest] != 0)
        probe_blind = jnp.any((probe_u >= 0) & (p_sz > Wp))
        pool = _scatter_entries(pool, tgt_blk, slot % bs, op_ok, sv, sw_, sts)

    was_live = jnp.where(fold_hit, fold_live, win_was_live)
    delta = jnp.sum(jnp.where(pair_last & ok2,
                              (w2 != 0).astype(jnp.int32) -
                              was_live.astype(jnp.int32), 0))

    # size += written count per group
    wrote = op_ok.astype(jnp.int32)
    wrote_per_group = jnp.zeros((B,), jnp.int32).at[g["gid"]].add(
        jnp.where(su < INT_MAX, wrote, 0))
    gtgt = jnp.where(g["gvalid"], g["gu"], n_cap)
    vt = vt._replace(size=vt.size.at[gtgt].add(
        wrote_per_group, mode="drop"))

    # updates/deletes eventually strand one stale entry each; a cheap upper
    # estimate (¼ of writes) drives the proactive half-garbage defrag trigger
    pool = pool._replace(clock=pool.clock + B,
                         garbage=pool.garbage + jnp.sum(wrote) // 4,
                         overflow=pool.overflow + jnp.where(dropped > 0, 1, 0),
                         live_m=pool.live_m + delta,
                         live_dirty=jnp.maximum(
                             pool.live_dirty,
                             ((dropped > 0) | probe_blind).astype(jnp.int32)),
                         tiles_scanned=pool.tiles_scanned + n_touched)
    return pool, vt, dropped


# --------------------------------------------------------------------------
# reads
# --------------------------------------------------------------------------

def get_neighbors(spec: PoolSpec, pool: EdgePool, vt: VertexTable,
                  u: jnp.ndarray, read_ts=None, width: int | None = None):
    """MVCC get-neighbors for a batch of vertex offsets.

    Returns (dst, weight, ts, count) with rows front-packed in reverse-scan
    order (paper's get_ngbrs = compaction-style scan, O(d))."""
    width = spec.dmax if width is None else width
    n_cap = vt.size.shape[0]
    d, w, t, size = _gather_vertex_entries(spec, pool, vt, u, width)
    # destination-visibility filter (paper: Del_time makes a vertex invisible)
    dt = vt.del_time[jnp.clip(d, 0, n_cap - 1)]
    if read_ts is None:
        dead = (d >= 0) & (dt != 0)
    else:
        rts = jnp.asarray(read_ts, jnp.int32)
        dead = (d >= 0) & (((dt > 0) & (dt <= rts)) | (dt == -1))
    d = jnp.where(dead, -1, d)
    rts = None if read_ts is None else jnp.asarray(read_ts, jnp.int32)
    return kops.compact_rows(d, w, t, size, read_ts=rts, impl=spec.compact_impl)


def live_edges(spec: PoolSpec, pool: EdgePool, vt: VertexTable, read_ts=None):
    """Flat snapshot of live edges: (owner, dst, weight, ts, keep_mask),
    sorted by (owner, dst). Input to analytics CSR construction."""
    bs = spec.block_size
    nb = pool.dst.shape[0]
    n_cap = vt.size.shape[0]
    N = nb * bs
    own = jnp.repeat(pool.owner, bs)
    d = pool.dst.reshape(-1)
    w = pool.weight.reshape(-1)
    t = pool.ts.reshape(-1)
    blk_index = jnp.arange(N, dtype=jnp.int32) // bs
    lane = jnp.arange(N, dtype=jnp.int32) % bs
    ownc = jnp.clip(own, 0, n_cap - 1)
    start = vt.start_block[ownc]
    pos = (blk_index - start) * bs + lane
    occupied = (own >= 0) & (pos >= 0) & (pos < vt.size[ownc])
    alive = (vt.del_time[ownc] == 0)
    dstc = jnp.clip(d, 0, n_cap - 1)
    dst_ok = (d >= 0) & (vt.del_time[dstc] == 0)
    valid = occupied & alive & dst_ok
    if read_ts is not None:
        valid = valid & (t <= jnp.asarray(read_ts, jnp.int32))
    SENT = INT_MAX
    so = jnp.where(valid, own, SENT)
    sd = jnp.where(valid, d, SENT)
    stv = jnp.where(valid, t, 0)
    order = jnp.lexsort((stv, sd, so))
    so, sd, sw, stv = so[order], sd[order], w[order], stv[order]
    nxt_o = jnp.concatenate([so[1:], jnp.full((1,), -2, so.dtype)])
    nxt_d = jnp.concatenate([sd[1:], jnp.full((1,), -2, sd.dtype)])
    is_last = ((so != nxt_o) | (sd != nxt_d)) & (so < SENT)
    keep = is_last & (sw != 0)
    return so, sd, sw, stv, keep
