"""Epoch-delta extraction: the exact edge changes between two captured
epochs of one (per-shard) ``GraphState``.

The paper's hybrid snapshot-log design makes the difference between two
sealed epochs a small log suffix — this module turns that suffix into a
typed ``EpochDelta`` the incremental analytics engine can consume
(``repro.analytics.incremental``): per-pair ``(src_row, dst_row,
w_prev, w_new)`` changes plus vertex-level events, derived WITHOUT
replaying ops.

Row offsets are the identity carrier: vertex rows are recycled into the
free ring only by a global defrag (``edgepool.defrag`` finalize), so
between two epochs with an equal ``pool.defrags`` counter every row
offset names the same vertex in both states and warm per-row value
arrays stay aligned. Extraction therefore REFUSES (returns ``None`` +
reason) whenever:

* ``pool.defrags`` differs — rows may have moved / been recycled;
* any overflow flag changed — dropped ops make the window unreliable;
* any vertex delete/revive happened — a vertex delete hides every
  incident edge (in- AND out-) at read time, so source rows far from the
  touched set change adjacency invisibly.

Touched-row detection is two cheap host passes, both sound under the
guards above:

1. vertex-table signature diff (``size``/``cap``/``start_block``/
   ``deg``/``del_time``) — catches appends, extent moves and per-vertex
   compactions that changed the footprint;
2. fresh log-entry scan — pool entries stamped ``ts >= prev_clock``
   (per-vertex compaction preserves entry timestamps, so any surviving
   window write marks its owner row even when the vt signature happens
   to collide).

A deletion window compacted away entirely shrinks ``size`` below the
previous live count (compaction keeps exactly the live entries), so the
union of the two passes covers every row whose adjacency changed.
Touched rows then get a sorted-CSR merge diff between the two epoch
snapshots — the effective per-pair changes, immune to how many log
records produced them.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.status import Reason

__all__ = ["EpochDelta", "HostCsr", "host_csr", "extract_delta",
           "extract_delta_sharded", "merged_flags"]


@dataclasses.dataclass(frozen=True)
class HostCsr:
    """Host (numpy) view of one shard's ``GraphSnapshot`` — built once per
    epoch and shared by the extractor and every host-side advance."""

    indptr: np.ndarray    # int32[n_cap + 1]
    dst: np.ndarray       # int32[m_cap] destination row offsets
    weight: np.ndarray    # float32[m_cap]
    active: np.ndarray    # bool[n_cap]
    ids: np.ndarray       # uint32[n_cap, 2]
    m: int                # live edge count

    @property
    def n_cap(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def deg(self) -> np.ndarray:
        return self.indptr[1:] - self.indptr[:-1]

    def vid64(self) -> np.ndarray:
        """Row -> 64-bit vertex ID."""
        return (self.ids[:, 0].astype(np.uint64) << np.uint64(32)) | \
            self.ids[:, 1].astype(np.uint64)


def host_csr(snap) -> HostCsr:
    """One host pull of a device ``GraphSnapshot`` (single shard)."""
    return HostCsr(indptr=np.asarray(snap.indptr),
                   dst=np.asarray(snap.dst),
                   weight=np.asarray(snap.weight),
                   active=np.asarray(snap.active),
                   ids=np.asarray(snap.ids),
                   m=int(np.asarray(snap.m)))


@dataclasses.dataclass(frozen=True)
class EpochDelta:
    """Effective changes between two epochs of one shard.

    Pair arrays are parallel: change k turned edge ``(e_src[k],
    e_dst[k])`` from weight ``w_prev[k]`` to ``w_new[k]`` (0.0 = absent /
    tombstoned on that side) — the NET effect, not the op log, so an
    insert+delete of the same pair inside the window vanishes here."""

    touched_rows: np.ndarray      # int32 — rows whose adjacency changed
    new_rows: np.ndarray          # int32 — rows allocated in the window
    e_src: np.ndarray             # int32[k]
    e_dst: np.ndarray             # int32[k]
    w_prev: np.ndarray            # float32[k]
    w_new: np.ndarray             # float32[k]
    m_prev: int                   # live edges at the previous epoch
    m_cur: int                    # live edges at the current epoch

    @property
    def n_changed(self) -> int:
        return int(self.e_src.shape[0])

    @property
    def inserts(self) -> np.ndarray:
        return (self.w_prev == 0.0) & (self.w_new != 0.0)

    @property
    def deletes(self) -> np.ndarray:
        return (self.w_prev != 0.0) & (self.w_new == 0.0)

    @property
    def updates(self) -> np.ndarray:
        return (self.w_prev != 0.0) & (self.w_new != 0.0)

    @property
    def has_deletes(self) -> bool:
        return bool(self.deletes.any())

    @property
    def has_weight_increase(self) -> bool:
        return bool((self.updates & (self.w_new > self.w_prev)).any())


def _vt_host(state) -> dict:
    vt = state.vt
    return dict(size=np.asarray(vt.size), cap=np.asarray(vt.cap),
                start=np.asarray(vt.start_block), deg=np.asarray(vt.deg),
                del_time=np.asarray(vt.del_time),
                num_rows=int(np.asarray(vt.num_rows)))


def _flags(state) -> Tuple[int, int, int, int]:
    return (int(np.asarray(state.pool.defrags)),
            int(np.asarray(state.sort.overflow)),
            int(np.asarray(state.vt.overflow)),
            int(np.asarray(state.pool.overflow)))


def _row_pairs(csr: HostCsr, r: int) -> Tuple[np.ndarray, np.ndarray]:
    lo, hi = int(csr.indptr[r]), int(csr.indptr[r + 1])
    return csr.dst[lo:hi], csr.weight[lo:hi]


def extract_delta(prev_state, cur_state, prev_csr: HostCsr,
                  cur_csr: HostCsr) -> Tuple[Optional[EpochDelta], Reason]:
    """Diff two captured epochs of ONE shard. Returns ``(delta, reason)``;
    ``delta is None`` means the window is not advance-safe and callers
    must recompute from scratch (``reason`` says why). Reasons are
    ``core.status.Reason`` members — ``str`` subclasses whose values are
    the legacy reason strings, so string consumers are unaffected."""
    pf, cf = _flags(prev_state), _flags(cur_state)
    if pf[0] != cf[0]:
        return None, Reason.DEFRAG       # rows may have been recycled
    if pf[1:] != cf[1:]:
        return None, Reason.OVERFLOW     # dropped ops in the window
    pvt, cvt = _vt_host(prev_state), _vt_host(cur_state)
    n_prev, n_cur = pvt["num_rows"], cvt["num_rows"]
    if n_cur < n_prev:
        return None, Reason.ROWS_SHRANK  # never expected without defrag
    # vertex delete / revive anywhere invalidates untouched source rows
    # (their in-edges to the deleted vertex vanish at read time)
    dt_p, dt_c = pvt["del_time"][:n_prev], cvt["del_time"][:n_prev]
    moved = dt_p != dt_c
    if bool((moved & ~((dt_p == -1) & (dt_c == 0))).any()):
        return None, Reason.VERTEX_EVENT

    sig = np.zeros((cur_csr.n_cap,), bool)
    for f in ("size", "cap", "start", "deg"):
        sig[:n_prev] |= pvt[f][:n_prev] != cvt[f][:n_prev]
    sig[:n_prev] |= moved

    # fresh log entries: per-vertex compaction and the bounded append both
    # preserve entry timestamps, so any surviving window write marks its
    # block's owner row (blocks are never recycled between defrags)
    prev_clock = int(np.asarray(prev_state.pool.clock))
    ts = np.asarray(cur_state.pool.ts)
    owner = np.asarray(cur_state.pool.owner)
    fresh_blocks = (ts >= prev_clock).any(axis=1) & (owner >= 0)
    fresh_rows = owner[fresh_blocks]
    sig[fresh_rows[fresh_rows < cur_csr.n_cap]] = True

    new_rows = np.arange(n_prev, n_cur, dtype=np.int32)
    sig[new_rows] = True
    touched = np.nonzero(sig)[0].astype(np.int32)

    es, ed, wp, wn = [], [], [], []
    for r in touched.tolist():
        pd, pw = (_row_pairs(prev_csr, r) if r < n_prev
                  else (np.zeros(0, np.int32), np.zeros(0, np.float32)))
        cd, cw = _row_pairs(cur_csr, r)
        if pd.shape == cd.shape and np.array_equal(pd, cd) and \
                np.array_equal(pw, cw):
            continue
        both = np.union1d(pd, cd).astype(np.int32)
        wpr = np.zeros(both.shape, np.float32)
        wpr[np.searchsorted(both, pd)] = pw
        wcu = np.zeros(both.shape, np.float32)
        wcu[np.searchsorted(both, cd)] = cw
        ch = wpr != wcu
        k = int(ch.sum())
        if k:
            es.append(np.full((k,), r, np.int32))
            ed.append(both[ch])
            wp.append(wpr[ch])
            wn.append(wcu[ch])

    cat = lambda xs, dt: (np.concatenate(xs) if xs
                          else np.zeros((0,), dt))
    return EpochDelta(
        touched_rows=touched, new_rows=new_rows,
        e_src=cat(es, np.int32), e_dst=cat(ed, np.int32),
        w_prev=cat(wp, np.float32), w_new=cat(wn, np.float32),
        m_prev=prev_csr.m, m_cur=cur_csr.m), Reason.OK


def _host_state_views(state, n_shards: int):
    """One host pull of the state fields extraction reads, sliced per
    shard on the HOST — slicing the device pytree per shard would issue
    hundreds of tiny device ops per window."""
    from types import SimpleNamespace as NS
    vt, pool, sort = state.vt, state.pool, state.sort
    h = {k: np.asarray(v) for k, v in dict(
        defrags=pool.defrags, pool_overflow=pool.overflow,
        clock=pool.clock, ts=pool.ts, owner=pool.owner,
        sort_overflow=sort.overflow, vt_overflow=vt.overflow,
        size=vt.size, cap=vt.cap, start_block=vt.start_block,
        deg=vt.deg, del_time=vt.del_time, num_rows=vt.num_rows).items()}
    return [NS(pool=NS(defrags=h["defrags"][s],
                       overflow=h["pool_overflow"][s],
                       clock=h["clock"][s], ts=h["ts"][s],
                       owner=h["owner"][s]),
               sort=NS(overflow=h["sort_overflow"][s]),
               vt=NS(overflow=h["vt_overflow"][s], size=h["size"][s],
                     cap=h["cap"][s], start_block=h["start_block"][s],
                     deg=h["deg"][s], del_time=h["del_time"][s],
                     num_rows=h["num_rows"][s]))
            for s in range(n_shards)]


def extract_delta_sharded(prev_state, cur_state, prev_csrs: List[HostCsr],
                          cur_csrs: List[HostCsr]
                          ) -> Tuple[Optional[List[EpochDelta]], str]:
    """Per-shard deltas over stacked sharded states (leading shard dim).
    Any shard refusing refuses the whole window — warm row alignment must
    hold everywhere."""
    n_shards = len(cur_csrs)
    pvs = _host_state_views(prev_state, n_shards)
    cvs = _host_state_views(cur_state, n_shards)
    out = []
    for s in range(n_shards):
        d, reason = extract_delta(pvs[s], cvs[s], prev_csrs[s],
                                  cur_csrs[s])
        if d is None:
            # sharded refusals carry the shard index as a prefix; the
            # suffix stays the enum value (a plain-string composite — the
            # shard attribution is diagnostic, the suffix is the code)
            return None, f"shard{s}:{reason}"
        out.append(d)
    return out, Reason.OK


def merged_flags(deltas: List[EpochDelta]) -> dict:
    """Aggregate advance-safety flags over per-shard deltas."""
    return dict(
        n_changed=sum(d.n_changed for d in deltas),
        m_prev=sum(d.m_prev for d in deltas),
        m_cur=sum(d.m_cur for d in deltas),
        has_deletes=any(d.has_deletes for d in deltas),
        has_weight_increase=any(d.has_weight_increase for d in deltas),
        new_rows=sum(int(d.new_rows.shape[0]) for d in deltas))
