"""SORT configuration optimizer (paper §3.2).

Finds the canonical l-layer radix-tree fan-outs ``a_0..a_{l-1}`` minimizing the
expected space

    min  2^{a_0} + sum_{i=1}^{l-1} N(i) * p(i) * 2^{a_i}
    s.t. a_0 + ... + a_{l-1} >= x

where N(i) = 2^{x - (a_i+...+a_{l-1})} is the max node count at layer i and
p(i) = 1 - C(2^x - S_i, n)/C(2^x, n) is the hypergeometric probability that a
layer-i node is instantiated, S_i = 2^{a_i+...+a_{l-1}}.

Solved exactly by the paper's dynamic program over prefix sums
``s_i = a_0+...+a_i`` (Equation 1), using Lemma 1 (``s_{l-1} = x``).

Pure numpy / Python — runs on host at graph-construction time (paper: <1 s on
twitter-2010; ours is O(l·x²) transitions with O(1) lgamma probability
evaluation instead of the paper's O(n) product).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "SortConfig",
    "optimize_sort",
    "expected_space",
    "uniform_config",
    "veb_config",
    "node_probability",
]


@dataclass(frozen=True)
class SortConfig:
    """A canonical l-layer radix tree configuration."""

    fanout_bits: Tuple[int, ...]  # a_i per layer, pruned of a_i == 0
    key_bits: int                 # x: bit length of the ID universe
    n: int                        # number of IDs the optimizer assumed
    expected_space: float         # objective value (pointer-slot count)

    @property
    def layers(self) -> int:
        return len(self.fanout_bits)

    @property
    def prefix_bits(self) -> Tuple[int, ...]:
        """s_i = a_0 + ... + a_i."""
        out, acc = [], 0
        for a in self.fanout_bits:
            acc += a
            out.append(acc)
        return tuple(out)

    @property
    def suffix_bits(self) -> Tuple[int, ...]:
        """Bits indexed strictly below layer i: x - s_i."""
        return tuple(self.key_bits - s for s in self.prefix_bits)


_EXACT_LIMIT = 1 << 22


def _log_comb_ratio(u: float, S: float, n: int) -> float:
    """ln[ C(u - S, n) / C(u, n) ].

    Exact product forms when either n or S is small (lgamma differences of
    huge arguments lose ~1e-5 absolute precision, which swamps tiny
    probabilities); Stirling-lgamma otherwise. Returns -inf when u - S < n
    (the node is then created with probability 1).
    """
    if u - S < n:
        return -math.inf
    if n <= _EXACT_LIMIT:
        # prod_{t<n} (u - S - t) / (u - t)
        t = np.arange(n, dtype=np.float64)
        return float(np.sum(np.log1p(-S / (u - t))))
    if S <= _EXACT_LIMIT:
        # C(u-S, n)/C(u, n) = prod_{t<S} (u - n - t) / (u - t)
        t = np.arange(int(S), dtype=np.float64)
        return float(np.sum(np.log1p(-n / (u - t))))
    ld = np.longdouble
    u, S = ld(u), ld(S)
    lg = _lgamma_ld
    return float(lg(u - S + 1) - lg(u - S - n + 1) - lg(u + 1) + lg(u - n + 1))


def _lgamma_ld(z: np.longdouble) -> np.longdouble:
    """lgamma for longdouble via Stirling series (z is huge here: >= 1).

    For z >= 1e7 uses Stirling with 3 correction terms (error << 1e-20
    relative); below that defers to math.lgamma (double is exact enough for
    small z).
    """
    zf = float(z)
    if zf < 1e7:
        return np.longdouble(math.lgamma(zf))
    ld = np.longdouble
    z = ld(z)
    half_log_2pi = ld(0.91893853320467274178032973640562)
    out = (z - ld(0.5)) * np.log(z) - z + half_log_2pi
    out += ld(1.0) / (ld(12.0) * z)
    out -= ld(1.0) / (ld(360.0) * z ** 3)
    out += ld(1.0) / (ld(1260.0) * z ** 5)
    return out


def node_probability(x: int, suffix_bits: int, n: int) -> float:
    """p(i): probability a layer-i node (interval size S = 2^suffix_bits) is
    instantiated when n distinct uniform IDs are drawn from [0, 2^x)."""
    if suffix_bits >= x:
        return 1.0
    u = math.pow(2.0, x)
    S = math.pow(2.0, suffix_bits)
    if u - S < n:
        return 1.0
    lr = _log_comb_ratio(u, S, n)
    # p = 1 - exp(lr); use expm1 for precision when lr ~ 0.
    return float(-math.expm1(lr)) if lr > -700 else 1.0


def expected_space(fanout_bits: Sequence[int], x: int, n: int) -> float:
    """Objective: expected pointer-slot count of the configuration.

    Layer 0 contributes 2^{a_0} (root always exists); layer i>0 contributes
    N(i) * p(i) * 2^{a_i} with N(i) = 2^{x - suffix(i)}, suffix(i) = bits
    strictly below *and including* layer i's fanout.
    """
    a = list(fanout_bits)
    l = len(a)
    if sum(a) < x:
        raise ValueError(f"configuration {a} cannot cover {x}-bit universe")
    total = math.pow(2.0, a[0])
    for i in range(1, l):
        suffix = sum(a[i:])            # a_i + ... + a_{l-1}
        prefix = sum(a[:i])            # bits consumed above layer i
        n_nodes = math.pow(2.0, max(x - suffix, 0))
        # Nodes beyond the universe are never created (paper case (2)).
        n_nodes = min(n_nodes, math.pow(2.0, prefix))
        p = node_probability(x, min(suffix, x), n)
        total += math.pow(2.0, a[i]) * min(n_nodes * p, float(n))
        # min(., n): at most n nodes can be instantiated at any layer — the
        # paper's expectation already satisfies N(i)p(i) <= n; the clamp only
        # guards float slack.
    return total


def optimize_sort(
    n: int,
    key_bits: int,
    layers: int,
    max_root_bits: int | None = None,
) -> SortConfig:
    """Solve the paper's DP (Equation 1) for the optimal fan-outs.

    g(i, j) = min space of the first i+1 layers given s_i = j, with
    g(0, j) = 2^j and transition cost h(j, k) = 2^j * p(suffix = x - k).
    Lemma 1 pins s_{l-1} = x. Backtracking recovers a_i = s_i - s_{i-1};
    zero-fanout layers are pruned (paper §3.2 "Tuning the depth").

    ``max_root_bits`` optionally caps a_0 (practical memory guard for the
    root pointer array; None = uncapped, faithful to the paper).
    """
    if n <= 0:
        raise ValueError("n must be positive")
    x = int(key_bits)
    l = max(1, min(int(layers), x))

    # h_cost[k] = multiplier term (1 - comb ratio) for a parent prefix of k
    # bits: nodes at the child layer have interval size 2^{x-k}; the expected
    # *count* of instantiated child-layer nodes is 2^k * p — but in the DP the
    # 2^j factor carries the array size, and N(i) = 2^{s_{i-1}} = 2^k nodes
    # each w.p. p(x - k)  →  term = 2^j * [N(i)p(i) / 2^{s_i - j} ... ]
    # Following the paper's simplified f: term_i = 2^{s_i} * p(x - s_{i-1}).
    p_of_prefix = [node_probability(x, x - k, n) for k in range(0, x + 1)]

    NEG = math.inf
    # g[j] for current layer; parent[i][j] = argmin k
    g_prev = [math.pow(2.0, j) for j in range(x + 1)]
    if max_root_bits is not None:
        for j in range(max_root_bits + 1, x + 1):
            g_prev[j] = NEG
    parents: List[List[int]] = []

    for i in range(1, l):
        g_cur = [NEG] * (x + 1)
        par = [-1] * (x + 1)
        # prefix minima of g_prev with the p factor applied lazily:
        # cost(j, k) = g_prev[k] + 2^j * p_of_prefix[k]; for fixed j the best
        # k must be found over k <= j. O(x^2) total per layer (x <= 64).
        for j in range(0, x + 1):
            pow2j = math.pow(2.0, j)
            best, bestk = NEG, -1
            for k in range(0, j):
                if g_prev[k] == NEG:
                    continue
                c = g_prev[k] + pow2j * p_of_prefix[k]
                if c < best:
                    best, bestk = c, k
            # k == j: a zero-width layer is *pruned* (paper §3.2 "Tuning the
            # depth"), so skipping a layer is free — this makes the DP exact
            # over the family of trees with AT MOST l layers.
            if g_prev[j] != NEG and g_prev[j] < best:
                best, bestk = g_prev[j], j
            g_cur[j] = best
            par[j] = bestk
        parents.append(par)
        g_prev = g_cur

    # Lemma 1: s_{l-1} = x.
    best_val = g_prev[x]
    s = [0] * l
    s[l - 1] = x
    for i in range(l - 1, 0, -1):
        s[i - 1] = parents[i - 1][s[i]]
    fanouts = [s[0]] + [s[i] - s[i - 1] for i in range(1, l)]
    fanouts = [a for a in fanouts if a > 0]  # prune zero layers
    if not fanouts:
        fanouts = [x]
    return SortConfig(
        fanout_bits=tuple(fanouts),
        key_bits=x,
        n=n,
        expected_space=float(best_val),
    )


def uniform_config(n: int, key_bits: int, layers: int) -> SortConfig:
    """Paper's uniform-tree baseline: equal fan-out 2^{ceil(x/l)}."""
    x, l = int(key_bits), max(1, int(layers))
    a = math.ceil(x / l)
    # uniform-tree uses fanout 2^{ceil(x/l)} at *every* layer (may overshoot x)
    fan = [a] * l
    return SortConfig(tuple(fan), x, n, expected_space(fan, x, n))


def veb_config(n: int, key_bits: int) -> SortConfig:
    """Paper's vEB baseline: recursively halve the bit budget.

    x -> top ceil(x/2) bits, then recurse on the lower half; yields fanouts
    (x/2, x/4, ..., 1) — depth O(lg x) = O(lglg u).
    """
    x = int(key_bits)
    fan: List[int] = []
    rem = x
    while rem > 0:
        top = (rem + 1) // 2
        fan.append(top)
        rem -= top
    return SortConfig(tuple(fan), x, n, expected_space(fan, x, n))
