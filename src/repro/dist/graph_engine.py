"""Vertex-space sharding of RadixGraph over a mesh axis.

Partitioning: ``owner(key) = hash(key) % n_shards`` on the SOURCE vertex —
every edge (u, v, w) lives in u's shard, so one shard holds a vertex's whole
edge array and answers its queries locally (RapidStore-style decoupled
per-partition state). Undirected graphs insert both directions host-side,
exactly like the single-node ``RadixGraph``.

A batched update step under ``shard_map``:

1. each shard hashes its slice of the global op batch and ranks ops into
   per-owner buckets of ``cap`` slots. With the default
   ``capacity_factor=1.0``, ``cap`` equals the per-shard slice, so routing is
   lossless — a source shard can never overflow one owner's bucket with ops
   from its own slice;
2. one ``all_to_all`` exchanges the buckets. With ``pack=True`` the five
   payloads (src hi/lo, dst hi/lo, weight bits, validity) travel as a single
   uint32 word-matrix — one collective launch instead of four;
3. each shard applies its received ops with the SAME pure transition the
   single-shard path uses (``core.radixgraph.step_update_edges``), returning
   a per-shard ``dropped`` count (capacity refusals, never UB).

Queries (``make_khop_counts``) route identically and the owner's answers ride
a second all_to_all back to the asking shard, which restores request order.

All functions close over static specs, so a jitted engine step is one fused
SPMD program: route -> exchange -> apply, no host round-trips.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import analytics as alg
from repro.core import edgepool as ep
from repro.core import radixgraph as rg
from repro.core import sort as sort_mod
from repro.core import vertex_table as vt_mod
from repro.core.radixgraph import GraphState
from repro.core.sort import SortSpec

__all__ = ["make_sharded_state", "make_apply_edges",
           "make_apply_edges_pipelined", "make_khop_counts",
           "make_sync_vertices", "make_snapshot", "make_bfs", "make_pagerank",
           "make_wcc", "make_sssp", "make_bc",
           "collect_owner_values", "shard_of_keys"]


def shard_of_keys(keys: jnp.ndarray, n_shards: int) -> jnp.ndarray:
    """Owner shard of each (..., 2) uint32 key — a cheap multiplicative hash
    with an xor-shift finalizer so dense ID ranges still spread evenly."""
    hi = keys[..., 0]
    lo = keys[..., 1]
    h = lo * jnp.uint32(0x9E3779B1) + hi * jnp.uint32(0x85EBCA77)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    return (h % jnp.uint32(n_shards)).astype(jnp.int32)


def make_sharded_state(sspec: SortSpec, pspec: ep.PoolSpec, n_shards: int,
                       n_per_shard: int) -> GraphState:
    """Fresh per-shard (SortState, VertexTable, EdgePool) pytrees stacked on
    a leading shard dim — the input/output carried by the engine's jitted
    step functions (shard dim maps onto the mesh axis)."""
    one = GraphState(
        sort=sort_mod.make_sort(sspec),
        vt=vt_mod.make_vertex_table(n_per_shard),
        pool=ep.make_edge_pool(pspec),
    )
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_shards,) + x.shape), one)


def _bucket_slots(owner: jnp.ndarray, valid: jnp.ndarray, cap: int):
    """Slot of each op in per-destination buckets of ``cap`` entries.

    Returns (slot, ok): ``slot = owner * cap + rank`` where rank is the op's
    stable order among same-owner ops; ``ok`` is False for invalid ops and
    bucket overflow (rank >= cap).
    """
    B = owner.shape[0]
    SENT = jnp.int32(0x7FFFFFFF)
    key = jnp.where(valid, owner, SENT)
    order = jnp.argsort(key, stable=True)
    so = key[order]
    idx = jnp.arange(B, dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), so[1:] != so[:-1]])
    start = jax.lax.cummax(jnp.where(first, idx, 0))
    rank_sorted = idx - start
    rank = jnp.zeros((B,), jnp.int32).at[order].set(rank_sorted)
    ok = valid & (rank < cap)
    return owner * cap + rank, ok


def _scatter_rows(x: jnp.ndarray, tgt: jnp.ndarray, n_rows: int, fill):
    out = jnp.full((n_rows,) + x.shape[1:], fill, x.dtype)
    return out.at[tgt].set(x, mode="drop")


# --------------------------------------------------------------------------
# frontier-compacted exchange
#
# The dense exchanges below move ``n_shards x n_cap`` buffers per round even
# when only a handful of rows carry data (sparse BFS frontiers, incremental
# vertex syncs). The compacted variant routes only the masked rows into
# count-prefixed buckets of a static ``budget`` rows per destination shard;
# a replicated psum decides OVERFLOW up front, and the caller conds into the
# dense path for that round, so results are bit-exact either way.
# --------------------------------------------------------------------------

def _route_overflow(owner, mask, n: int, budget: int, axis: str):
    """Replicated: does any shard route > budget rows to one destination?"""
    counts = jnp.zeros((n,), jnp.int32).at[
        jnp.where(mask, owner, n)].add(1, mode="drop")
    over = jnp.any(counts > budget).astype(jnp.int32)
    return jax.lax.psum(over, axis) > 0


def _route_dense(owner, mask, payload, n: int, cap: int, a2a):
    """Lossless dense route: bucket capacity ``cap`` rows per destination,
    validity as a trailing flag column. Returns (rows (n*cap, C), valid)."""
    C = payload.shape[1]
    slot, ok = _bucket_slots(owner, mask, cap)
    p = jnp.concatenate([payload, ok.astype(jnp.uint32)[:, None]], axis=1)
    buf = _scatter_rows(p, jnp.where(ok, slot, n * cap), n * cap, 0)
    r = a2a(buf.reshape(n, cap, C + 1)).reshape(n * cap, C + 1)
    return r[:, :C], r[:, C] == 1


def _route_compact(owner, mask, payload, n: int, budget: int, a2a):
    """Count-prefixed compacted route: per destination shard one header row
    (its [0] word = row count) + ``budget`` data rows. The caller must have
    established via ``_route_overflow`` that no bucket spills.
    Returns (rows (n*budget, C), valid)."""
    C = payload.shape[1]
    stride = budget + 1
    slot, ok = _bucket_slots(owner, mask, budget)
    # data row at owner*stride + 1 + rank; slot//budget == owner for ok rows
    tgt = jnp.where(ok, slot + slot // budget + 1, n * stride)
    counts = jnp.zeros((n,), jnp.uint32).at[
        jnp.where(ok, owner, n)].add(1, mode="drop")
    buf = jnp.zeros((n * stride, C), jnp.uint32).at[tgt].set(
        payload.astype(jnp.uint32), mode="drop")
    buf = buf.at[jnp.arange(n) * stride, 0].set(counts)
    r = a2a(buf.reshape(n, stride, C))
    cnt = r[:, 0, 0].astype(jnp.int32)
    rows = r[:, 1:, :].reshape(n * budget, C)
    valid = (jnp.arange(budget, dtype=jnp.int32)[None, :] <
             cnt[:, None]).reshape(-1)
    return rows, valid


def _pack_qbits(b: jnp.ndarray) -> jnp.ndarray:
    """(R, Q) bool -> (R, ceil(Q/32)) uint32 word matrix (bit q of word
    q//32). Distinct powers of two make the sum an OR."""
    R, Q = b.shape
    QW = (Q + 31) // 32
    bp = jnp.pad(b, ((0, 0), (0, QW * 32 - Q))).reshape(R, QW, 32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bp.astype(jnp.uint32) * weights[None, None, :], axis=-1)


def _unpack_qbits(words: jnp.ndarray, Q: int) -> jnp.ndarray:
    R, QW = words.shape
    bits = (words[:, :, None] >>
            jnp.arange(32, dtype=jnp.uint32)[None, None, :]) & 1
    return bits.reshape(R, QW * 32)[:, :Q] == 1


def _popcount_rows(words: jnp.ndarray) -> jnp.ndarray:
    """Per-row set-bit count of a (R, W) uint32 word matrix."""
    return jnp.sum(jax.lax.population_count(words).astype(jnp.int32),
                   axis=-1)


def make_apply_edges(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                     pack: bool = True, capacity_factor: float = 1.0,
                     route_budget: Optional[int] = None):
    """Build ``apply(state, src_keys, dst_keys, w, mask) -> (state, dropped)``.

    Inputs are GLOBAL batches: (B, 2) uint32 keys, (B,) f32 weights (0 =
    delete), (B,) bool mask, with B divisible by the shard count; ``state``
    is a ``make_sharded_state`` pytree. ``dropped`` is int32[n_shards] —
    per-shard refused ops (routing overflow when capacity_factor < 1, vertex
    table / pool exhaustion otherwise).

    ``route_budget`` compacts the op exchange: ops ride count-prefixed
    buckets of that many rows per destination shard (cutting collective
    bytes when the hash spread is even), falling back to the dense lossless
    route — still applied through the SAME pure transition — whenever a
    bucket would spill. Lossless either way, so ``dropped`` keeps meaning
    capacity refusals only.
    """
    n = int(mesh.shape[axis])
    apply_one = _make_shard_batch_apply(sspec, pspec, n, axis, pack,
                                        capacity_factor, route_budget)

    def body(state, sk, dk, w, mask):
        g = jax.tree.map(lambda x: x[0], state)
        g, dropped = apply_one(g, sk, dk, w, mask)
        return jax.tree.map(lambda x: x[None], g), dropped[None]

    sharded = shard_map(body, mesh=mesh,
                        in_specs=(P(axis), P(axis), P(axis), P(axis),
                                  P(axis)),
                        out_specs=(P(axis), P(axis)), check_rep=False)

    def apply_edges(state, src_keys, dst_keys, w, mask):
        B = src_keys.shape[0]
        assert B % n == 0, f"global op batch {B} not divisible by {n} shards"
        return sharded(state, src_keys, dst_keys, w, mask)

    return apply_edges


def _make_shard_batch_apply(sspec: SortSpec, pspec: ep.PoolSpec, n: int,
                            axis: str, pack: bool, capacity_factor: float,
                            route_budget: Optional[int]):
    """Shard-local routed apply of ONE op batch, shared by the per-batch and
    pipelined engine factories: ``(g, sk, dk, w, mask) -> (g, dropped)`` with
    unstacked per-shard state ``g`` and a scalar ``dropped``."""

    def apply_one(g, sk, dk, w, mask):
        Bl = sk.shape[0]
        cap = max(1, int(round(Bl * capacity_factor)))
        owner = shard_of_keys(sk, n)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        if route_budget is not None:
            payload = jnp.stack(
                [sk[:, 0], sk[:, 1], dk[:, 0], dk[:, 1],
                 jax.lax.bitcast_convert_type(w, jnp.uint32)], axis=-1)

            def apply_rows(rows, valid):
                rw = jax.lax.bitcast_convert_type(rows[:, 4], jnp.float32)
                return rg.step_update_edges(sspec, pspec, g, rows[:, 0:2],
                                            rows[:, 2:4], rw, valid)

            ovf = _route_overflow(owner, mask, n, route_budget, axis)
            return jax.lax.cond(
                ovf,
                lambda _: apply_rows(*_route_dense(owner, mask, payload, n,
                                                   Bl, a2a)),
                lambda _: apply_rows(*_route_compact(owner, mask, payload,
                                                     n, route_budget, a2a)),
                None)
        slot, ok = _bucket_slots(owner, mask, cap)
        route_drop = jnp.sum((mask & ~ok).astype(jnp.int32))
        NC = n * cap
        tgt = jnp.where(ok, slot, NC)
        if pack:
            payload = jnp.stack(
                [sk[:, 0], sk[:, 1], dk[:, 0], dk[:, 1],
                 jax.lax.bitcast_convert_type(w, jnp.uint32),
                 ok.astype(jnp.uint32)], axis=-1)            # (Bl, 6) u32
            buf = _scatter_rows(payload, tgt, NC, 0)
            r = a2a(buf.reshape(n, cap, 6)).reshape(NC, 6)
            rsk, rdk = r[:, 0:2], r[:, 2:4]
            rw = jax.lax.bitcast_convert_type(r[:, 4], jnp.float32)
            rmask = r[:, 5] == 1
        else:
            def xch(x, fill):
                buf = _scatter_rows(x, tgt, NC, fill)
                return a2a(buf.reshape((n, cap) + x.shape[1:])).reshape(
                    (NC,) + x.shape[1:])
            rsk = xch(sk, 0)
            rdk = xch(dk, 0)
            rw = xch(w, 0.0)
            rmask = xch(ok.astype(jnp.uint32), 0) == 1
        g, dropped = rg.step_update_edges(sspec, pspec, g, rsk, rdk, rw,
                                          rmask)
        return g, dropped + route_drop

    return apply_one


def make_apply_edges_pipelined(sspec: SortSpec, pspec: ep.PoolSpec, mesh,
                               axis: str, pack: bool = True,
                               capacity_factor: float = 1.0,
                               route_budget: Optional[int] = None):
    """Build ``apply(state, src_keys, dst_keys, w, mask) -> (state, dropped)``
    over a STACKED (K, B, ...) super-batch: one ``lax.scan`` of the routed
    per-batch transition inside a single shard_map program, so K batches cost
    ONE dispatch and zero host round-trips mid-stream.

    Identical semantics to K sequential ``make_apply_edges`` calls (same
    routing, same ``step_update_edges``, same overflow-defrag fallback — all
    device-side), with the drop counter accumulated on device and returned as
    one int32[n_shards] summed over the K batches. ``tiles_scanned`` /
    ``defrags`` likewise accumulate in the pool scalars, so callers fetch
    stats once per flush instead of once per batch.
    """
    n = int(mesh.shape[axis])
    apply_one = _make_shard_batch_apply(sspec, pspec, n, axis, pack,
                                        capacity_factor, route_budget)

    def body(state, sks, dks, ws, masks):
        g = jax.tree.map(lambda x: x[0], state)

        def step(gc, xs):
            return apply_one(gc, *xs)

        g, drops = jax.lax.scan(step, g, (sks, dks, ws, masks))
        return (jax.tree.map(lambda x: x[None], g),
                jnp.sum(drops, dtype=jnp.int32)[None])

    sharded = shard_map(body, mesh=mesh,
                        in_specs=(P(axis), P(None, axis), P(None, axis),
                                  P(None, axis), P(None, axis)),
                        out_specs=(P(axis), P(axis)), check_rep=False)

    def apply_edges_pipelined(state, src_keys, dst_keys, w, mask):
        K, B = src_keys.shape[0], src_keys.shape[1]
        assert B % n == 0, f"global op batch {B} not divisible by {n} shards"
        assert w.shape == (K, B) and mask.shape == (K, B)
        return sharded(state, src_keys, dst_keys, w, mask)

    return apply_edges_pipelined


def make_khop_counts(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                     k: int = 1, read_ts: Optional[int] = None,
                     m_cap: Optional[int] = None,
                     frontier_budget: Optional[int] = None):
    """Build ``khop(state, query_keys) -> int32[Q]``: live (deduplicated)
    k-hop neighbourhood counts for arbitrary query keys. Queries are routed
    with the same hash partition as updates.

    k == 1 with ``m_cap=None`` answers out-degree straight off the owner's
    edge array (0 for absent vertices, self-loops count) with a route +
    return all_to_all — the degree-query fast path. With ``m_cap`` set,
    k == 1 runs the frontier body below instead, matching
    ``analytics.khop`` exactly (distinct neighbors, source/self-loop
    excluded).

    k in (2, 3) runs BOUNDED frontier rounds over per-shard CSR snapshots
    (requires ``m_cap`` and a vertex-SYNCED state): every round each shard
    expands all queries' frontiers through its local CSR, discoveries ride
    ONE exchange as (id, query-bitmask-words) rows — compacted under
    ``frontier_budget`` with dense fallback — and owners dedup/mark them.
    The count is Σ visited owner rows (psum-replicated) minus the source,
    matching ``analytics.khop``: distinct vertices within <= k hops,
    source excluded; 0 for absent sources."""
    n = int(mesh.shape[axis])
    if k not in (1, 2, 3):
        raise NotImplementedError("khop counts support k <= 3 (bounded "
                                  "frontier rounds)")
    if k > 1 and m_cap is None:
        raise ValueError("k >= 2 requires m_cap for the CSR snapshot")

    def body_degree(state, qk):
        g = jax.tree.map(lambda x: x[0], state)
        Ql = qk.shape[0]
        owner = shard_of_keys(qk, n)
        slot, _ = _bucket_slots(owner, jnp.ones((Ql,), bool), Ql)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        buf = _scatter_rows(qk, slot, n * Ql, 0)
        recv = a2a(buf.reshape(n, Ql, 2)).reshape(n * Ql, 2)
        # unrouted slots hold key 0: their answers are never read back
        cnt = rg.step_degree_counts(sspec, pspec, g, recv, read_ts=read_ts)
        back = a2a(cnt.reshape(n, Ql)).reshape(-1)
        return back[slot]

    def body_khop(state, qk):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        Ql = qk.shape[0]
        Qtot = n * Ql
        QW = (Qtot + 31) // 32
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, read_ts)
        edges = alg.csr_edges(snap)
        my, rowlive, owner, _mine = _row_meta(sspec, g, n, axis)

        # route queries to their owner shards (source rows live there). The
        # slot is (owner, source-local index) — NOT a bucket rank — so the
        # receiver-side position (source shard, index) names each query
        # GLOBALLY: every shard's visited/frontier bit q means the same
        # query, which the final psum relies on.
        qowner = shard_of_keys(qk, n)
        idx = jnp.arange(Ql, dtype=jnp.int32)
        # a validity column rides along: an unrouted slot holds key (0, 0),
        # which would otherwise alias a real vertex id 0 and seed its
        # neighborhood into the psum'd counts of the query sharing the slot
        qpay = jnp.concatenate([qk, jnp.ones((Ql, 1), jnp.uint32)], axis=1)
        buf = _scatter_rows(qpay, qowner * Ql + idx, Qtot, 0)
        recv = a2a(buf.reshape(n, Ql, 3)).reshape(Qtot, 3)
        roff = jnp.where(recv[:, 2] == 1,
                         sort_mod.lookup(sspec, g.sort, recv[:, 0:2]), -1)
        qidx = jnp.arange(Qtot, dtype=jnp.int32)
        # per-query visited/frontier carries are BITMAP-PACKED: uint32
        # words over vertex offsets ((Qtot, n_cap/32) instead of the
        # (Qtot, n_cap) bool slabs), 32x less carried state at pod-scale
        # query batches; expansion transiently unpacks one frontier at a
        # time and the final count is a popcount, so the packed loop is
        # value-identical to the bool one (the parity tests against
        # ``analytics.khop`` pin that down)
        VW = (n_cap + 31) // 32
        visited_w = jnp.zeros((Qtot, VW + 1), jnp.uint32).at[
            qidx, jnp.where(roff >= 0, roff >> 5, VW)].set(
                jnp.where(roff >= 0,
                          jnp.uint32(1) << (roff & 31).astype(jnp.uint32),
                          jnp.uint32(0)))[:, :VW]
        frontier_w = visited_w

        payload_ids = jnp.stack([g.vt.ids[:, 0], g.vt.ids[:, 1]], axis=-1)

        def mark(rows, valid):
            ro = sort_mod.lookup(sspec, g.sort, rows[:, 0:2])
            okr = valid & (ro >= 0)
            flags = _unpack_qbits(rows[:, 2:], Qtot) & okr[:, None]
            hit = jnp.zeros((n_cap + 1, Qtot), bool).at[
                jnp.where(okr, ro, n_cap)].max(flags)
            return hit[:n_cap].T    # (Qtot, n_cap), owner rows only

        for _hop in range(k):
            frontier = _unpack_qbits(frontier_w, n_cap)   # transient
            exp = jax.vmap(lambda f: alg.bfs_expand(snap, f, edges))(frontier)
            qwords = _pack_qbits(exp.T)            # (n_cap, QW)
            mask_rows = rowlive & jnp.any(exp, axis=0)
            payload = jnp.concatenate([payload_ids, qwords], axis=1)
            if frontier_budget is None:
                hit = mark(*_route_dense(owner, mask_rows, payload, n,
                                         n_cap, a2a))
            else:
                ovf = _route_overflow(owner, mask_rows, n, frontier_budget,
                                      axis)
                hit = jax.lax.cond(
                    ovf,
                    lambda _: mark(*_route_dense(owner, mask_rows, payload,
                                                 n, n_cap, a2a)),
                    lambda _: mark(*_route_compact(owner, mask_rows, payload,
                                                   n, frontier_budget, a2a)),
                    None)
            frontier_w = _pack_qbits(hit) & ~visited_w
            visited_w = visited_w | frontier_w

        counts = jax.lax.psum(_popcount_rows(visited_w), axis)
        counts = jnp.maximum(counts - 1, 0)  # drop the source; absent -> 0
        return counts[my * Ql + idx]         # psum-replicated: no return hop

    body = body_degree if (k == 1 and m_cap is None) else body_khop
    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                        out_specs=P(axis), check_rep=False)

    def khop(state, query_keys):
        Q = query_keys.shape[0]
        assert Q % n == 0, f"query batch {Q} not divisible by {n} shards"
        return sharded(state, query_keys)

    return khop


# --------------------------------------------------------------------------
# distributed read path: per-shard CSR snapshots + level-synchronous
# analytics with frontier / inflow exchange over the mesh axis
#
# Edges live in the SOURCE vertex's shard, so a shard's CSR covers exactly
# its local rows; a vertex that only appears as a destination has stub rows
# (no edges) in source shards. ``make_sync_vertices`` registers every live
# row's ID at its hash-owner so that each vertex has exactly one OWNER row —
# the row analytics results are accumulated at and read from.
# --------------------------------------------------------------------------

def _row_meta(sspec, g: GraphState, n: int, axis: str):
    """Per-local-row metadata shared by the distributed analytics bodies."""
    my = jax.lax.axis_index(axis)
    rowlive = g.vt.del_time == 0
    owner = shard_of_keys(g.vt.ids, n)
    return my, rowlive, owner, rowlive & (owner == my)


def make_sync_vertices(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                       budget: Optional[int] = None,
                       incremental: bool = False):
    """Build ``sync(state) -> state``: every live local row's vertex ID is
    routed to its hash-owner shard and locate-or-inserted there, so each
    vertex gains an owner row even if it only ever appeared as an edge
    destination. Idempotent; run once before distributed analytics.

    ``incremental=True`` builds ``sync(state, prev_rows) -> state`` instead:
    only rows with index >= ``prev_rows[shard]`` (i.e. created since the
    caller last synced — valid while vertex rows are never recycled, which
    holds for delete-free services) are exchanged, so steady-state syncs
    cost O(new vertices). With ``budget`` set, the exchange ships
    count-prefixed compacted buckets of that many rows per destination and
    falls back to the dense lossless route when a bucket would spill."""
    n = int(mesh.shape[axis])

    def body(state, *prev):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        rowlive = g.vt.del_time == 0
        if incremental:
            prev_rows = prev[0][0]
            rowlive = rowlive & (jnp.arange(n_cap, dtype=jnp.int32) >=
                                 prev_rows)
        owner = shard_of_keys(g.vt.ids, n)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        payload = jnp.stack([g.vt.ids[:, 0], g.vt.ids[:, 1]], axis=-1)

        def register(rows, valid):
            st, vt, _, _ = vt_mod.ensure_vertices(sspec, g.sort, g.vt,
                                                  rows[:, 0:2], valid)
            return GraphState(st, vt, g.pool)

        if budget is None:
            g2 = register(*_route_dense(owner, rowlive, payload, n, n_cap,
                                        a2a))
        else:
            ovf = _route_overflow(owner, rowlive, n, budget, axis)
            g2 = jax.lax.cond(
                ovf,
                lambda _: register(*_route_dense(owner, rowlive, payload, n,
                                                 n_cap, a2a)),
                lambda _: register(*_route_compact(owner, rowlive, payload,
                                                   n, budget, a2a)),
                None)
        return jax.tree.map(lambda x: x[None], g2)

    in_specs = (P(axis),) + ((P(axis),) if incremental else ())
    sharded = shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=P(axis), check_rep=False)
    return sharded


def make_snapshot(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                  m_cap: int, read_ts: Optional[int] = None):
    """Build ``snap(state) -> GraphSnapshot`` with a leading shard dim: each
    shard builds the CSR of ITS slice of the edge set (dst column holds
    local row offsets) under shard_map — the distributed analogue of
    ``RadixGraph.snapshot``, one fused SPMD program, no host gather."""

    def body(state):
        g = jax.tree.map(lambda x: x[0], state)
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, read_ts)
        return jax.tree.map(lambda x: x[None], snap)

    return shard_map(body, mesh=mesh, in_specs=(P(axis),),
                     out_specs=P(axis), check_rep=False)


def make_bfs(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
             m_cap: int, max_iters: int = 32,
             frontier_budget: Optional[int] = None):
    """Build ``bfs(state, source_key) -> int32[n_shards, n_cap]`` — level-
    synchronous distributed BFS. Per level each shard expands its LOCAL CSR
    (``analytics.bfs_expand``), then newly-discovered row IDs are exchanged
    to their owner shards, which mark depth and seed the next frontier.
    Depths are authoritative at owner rows (-1 unreachable); stub rows may
    record the level their shard first saw the vertex. Run on a
    vertex-synced state (``make_sync_vertices``).

    ``frontier_budget`` compacts the per-level exchange: discoveries ship in
    count-prefixed buckets of that many rows per destination shard (dense
    rounds fall back to the lossless n_cap route, decided by a replicated
    psum per level, so depths stay bit-exact)."""
    n = int(mesh.shape[axis])

    def body(state, source_key):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, None)
        edges = alg.csr_edges(snap)   # loop-invariant: built once, not per level
        my, rowlive, owner, _mine = _row_meta(sspec, g, n, axis)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        payload = jnp.stack([g.vt.ids[:, 0], g.vt.ids[:, 1]], axis=-1)

        def mark_hits(rows, valid):
            roff = sort_mod.lookup(sspec, g.sort, rows[:, 0:2])
            seen = valid & (roff >= 0)
            return jnp.zeros((n_cap + 1,), bool).at[
                jnp.where(seen, roff, n_cap)].max(True)[:n_cap]

        def exchange(new_local):
            if frontier_budget is None:
                return mark_hits(*_route_dense(owner, new_local, payload, n,
                                               n_cap, a2a))
            ovf = _route_overflow(owner, new_local, n, frontier_budget, axis)
            return jax.lax.cond(
                ovf,
                lambda _: mark_hits(*_route_dense(owner, new_local, payload,
                                                  n, n_cap, a2a)),
                lambda _: mark_hits(*_route_compact(owner, new_local,
                                                    payload, n,
                                                    frontier_budget, a2a)),
                None)

        off0 = sort_mod.lookup(sspec, g.sort, source_key[None, :])[0]
        row = jnp.arange(n_cap, dtype=jnp.int32)
        depth0 = jnp.where(row == off0, 0, -1)
        frontier0 = (row == off0) & rowlive
        go0 = jax.lax.psum(jnp.any(frontier0).astype(jnp.int32), axis) > 0

        def cond(c):
            _, _, it, go = c
            return go & (it < max_iters)

        def lvl(c):
            depth, frontier, it, _ = c
            new_local = alg.bfs_expand(snap, frontier, edges) & (depth < 0)
            # stub rows are marked locally (each row notifies at most once);
            # owner rows are marked via the exchange below, which also
            # dedups discoveries arriving from several shards at once
            hit = exchange(new_local)
            depth = jnp.where(new_local & (owner != my), it + 1, depth)
            nxt = hit & (depth < 0)
            depth = jnp.where(nxt, it + 1, depth)
            go = jax.lax.psum(jnp.any(nxt).astype(jnp.int32), axis) > 0
            return depth, nxt, it + 1, go

        depth, _, _, _ = jax.lax.while_loop(
            cond, lvl, (depth0, frontier0, jnp.int32(0), go0))
        return depth[None]

    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis), P()),
                        out_specs=P(axis), check_rep=False)
    return sharded


def _owner_value_route(sspec, g: GraphState, n: int, axis: str, a2a, owner,
                       rowlive, budget: Optional[int], impl):
    """Run ``impl(rtgt, fwd, bwd)`` under the live-row -> owner-row exchange
    shared by every iterative combine loop (PageRank inflow, WCC labels,
    SSSP distances, BC sigma/delta).

    The route is data-independent — every live local row ships to its
    hash-owner's shard — so it is resolved ONCE per program: a key exchange
    binds each receiver slot to one of the receiver's own rows (``rtgt``),
    and per iteration only VALUES move:

      ``fwd(vals)``   (n_cap, C) per-local-row values -> (R, C) routed rows
                      at the receiver, aligned with ``rtgt`` (combine with a
                      ``.at[rtgt].add/min`` scatter; slot n_cap is the dump);
      ``bwd(merged)`` (n_cap + 1, C) owner-merged values -> ((n_cap, C), ok):
                      every routed row reads its owner's merged value back
                      over the inverse all_to_all (``ok`` marks routed rows).

    With ``budget`` set the exchange ships count-prefixed compacted buckets
    (``_route_compact``) whenever no bucket spills — decided by ONE
    replicated psum up front, since the route never changes mid-run — and
    falls back to the dense lossless layout otherwise, so results are
    identical either way."""
    n_cap = g.vt.del_time.shape[0]
    keys2 = jnp.stack([g.vt.ids[:, 0], g.vt.ids[:, 1]], axis=-1)

    def build(compact: bool):
        if compact:
            F = budget
            stride = F + 1
            rows, valid = _route_compact(owner, rowlive, keys2, n, F, a2a)
            slot, ok = _bucket_slots(owner, rowlive, F)
            tgt = jnp.where(ok, slot + slot // F + 1, n * stride)
        else:
            F = n_cap
            stride = n_cap
            rows, valid = _route_dense(owner, rowlive, keys2, n, n_cap, a2a)
            slot, ok = _bucket_slots(owner, rowlive, n_cap)
            tgt = jnp.where(ok, slot, n * stride)
        R = n * F
        roff = sort_mod.lookup(sspec, g.sort, rows[:, 0:2])
        rtgt = jnp.where(valid & (roff >= 0), roff, n_cap)
        tgtc = jnp.clip(tgt, 0, n * stride - 1)

        def fwd(vals):
            C = vals.shape[1]
            vbuf = jnp.zeros((n * stride, C), vals.dtype).at[tgt].set(
                vals, mode="drop")
            r = a2a(vbuf.reshape(n, stride, C))
            if compact:
                r = r[:, 1:, :]
            return r.reshape(R, C)

        def bwd(merged):
            ans = merged[rtgt]                                    # (R, C)
            C = ans.shape[1]
            if compact:
                abuf = jnp.zeros((n, stride, C), ans.dtype).at[
                    :, 1:, :].set(ans.reshape(n, F, C))
                back = a2a(abuf).reshape(n * stride, C)
            else:
                back = a2a(ans.reshape(n, stride, C)).reshape(n * stride, C)
            return back[tgtc], ok

        return rtgt, fwd, bwd

    if budget is None:
        return impl(*build(False))
    ovf = _route_overflow(owner, rowlive, n, budget, axis)
    return jax.lax.cond(ovf,
                        lambda _: impl(*build(False)),
                        lambda _: impl(*build(True)), None)


def make_pagerank(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                  m_cap: int, iters: int = 20, damping: float = 0.85,
                  frontier_budget: Optional[int] = None,
                  tol: Optional[float] = None, warm: bool = False):
    """Build ``pr(state) -> float32[n_shards, n_cap]`` — distributed
    PageRank. Ranks live at owner rows; per iteration each shard scatters
    contributions along its local CSR (``analytics.pagerank_scatter``) and
    routes every live row's accumulated inflow back to the row's owner over
    one all_to_all (the combine phase). Dangling mass and the active count
    are psums over owner rows. Run on a vertex-synced state.

    ``tol=None`` (default) is the fixed-``iters`` scan — bit-identical to
    the pre-incremental program. ``tol`` set switches to a convergence
    while_loop (stop when the owner-row ``max|Δpr|`` pmax drops under
    ``tol``, ``iters`` now a cap) returning ``(pr, iters_run)``; ``warm``
    additionally takes a ``(n_shards, n_cap)`` float32 seed (negative =
    no previous value, start uniform) — the damped map has ONE fixed
    point, so warm and cold starts converge to the same answer and the
    warm program is the epoch-advance path.

    The inflow route is data-independent (every live row -> its owner), so
    with ``frontier_budget`` the whole run compacts when the live rows fit
    the budget (one replicated psum up front; otherwise the dense route runs
    unchanged). Per-target add order is preserved, so ranks match the dense
    path bit-for-bit."""
    n = int(mesh.shape[axis])
    assert not (warm and tol is None), "warm PageRank needs a tol"

    def body(state, *extra):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, None)
        edges = alg.csr_edges(snap)   # loop-invariant: built once, not per iter
        my, rowlive, owner, mine = _row_meta(sspec, g, n, axis)
        deg = (snap.indptr[1:] - snap.indptr[:-1]).astype(jnp.float32)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)

        n_act = jnp.maximum(jax.lax.psum(
            jnp.sum(mine.astype(jnp.float32)), axis), 1.0)
        pr0 = jnp.where(mine, 1.0 / n_act, 0.0)
        if warm:
            w0 = extra[0][0]
            pr0 = jnp.where(mine & (w0 >= 0), w0, pr0)

        def impl(rtgt, fwd, bwd):
            def one(pr):
                contrib = alg.pagerank_contrib(snap, pr)
                local_in = alg.pagerank_scatter(snap, contrib, edges)
                rv = fwd(local_in[:, None])[:, 0]
                inflow = jnp.zeros((n_cap + 1,)).at[rtgt].add(rv)[:n_cap]
                dangling = jax.lax.psum(
                    jnp.sum(jnp.where(mine & (deg == 0), pr, 0.0)), axis)
                return jnp.where(mine, (1 - damping) / n_act +
                                 damping * (inflow + dangling / n_act), 0.0)

            if tol is None:
                pr, _ = jax.lax.scan(lambda pr, _: (one(pr), None), pr0,
                                     None, length=iters)
                return pr

            def cond(c):
                _, ch, it = c
                return (ch >= tol) & (it < iters)

            def step(c):
                pr, _, it = c
                nxt = one(pr)
                ch = jax.lax.pmax(jnp.max(jnp.where(
                    mine, jnp.abs(nxt - pr), 0.0)), axis)
                return nxt, ch, it + 1

            pr, _, it = jax.lax.while_loop(
                cond, step, (pr0, jnp.float32(jnp.inf), jnp.int32(0)))
            return pr, it

        out = _owner_value_route(sspec, g, n, axis, a2a, owner, rowlive,
                                 frontier_budget, impl)
        if tol is None:
            return out[None]
        pr, it = out
        return pr[None], it[None]

    in_specs = (P(axis),) + ((P(axis),) if warm else ())
    out_specs = P(axis) if tol is None else (P(axis), P(axis))
    sharded = shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    return sharded


def make_wcc(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
             m_cap: int, max_iters: int = 64,
             frontier_budget: Optional[int] = None, warm: bool = False):
    """Build ``wcc(state) -> uint32[n_shards, n_cap]`` — distributed weakly
    connected components by min-label propagation. Labels are CANONICAL
    across shard counts: each component converges to the minimum live vertex
    ID in it (the single-shard ``analytics.wcc`` reference uses row offsets;
    compare after mapping its labels to per-component min IDs). Requires a
    <= 32-bit ID universe (keys' hi word zero) so a label is one uint32 —
    every graph path in this repo packs 32-bit IDs. Assumes symmetric
    (undirected) edge insertion like the reference. Run on a vertex-synced
    state; 0xFFFFFFFF marks dead rows.

    Per round each shard pulls the min label over its LOCAL edges, then
    every live row's label rides the owner exchange: owners merge with a
    min-scatter and the merged label is broadcast back over the inverse
    all_to_all, so every copy of a vertex re-enters the next round with the
    global value. Terminates when no OWNER row improved (exact: copies are
    equal at round start, so any improvement lowers the owner's min).

    ``warm`` adds a ``(n_shards, n_cap)`` uint32 label seed (a previous
    epoch's output verbatim — UMAX at then-dead rows is the identity under
    min) and returns ``(labels, iters_run)``. Insert-only deltas only merge
    components, so prev labels are still valid upper bounds and propagation
    reaches the same min-ID fixed point in far fewer rounds."""
    n = int(mesh.shape[axis])
    UMAX = jnp.uint32(0xFFFFFFFF)

    def body(state, *extra):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, None)
        src, ok_e, dst = alg.csr_edges(snap)
        srcc = jnp.clip(src, 0, n_cap - 1)
        my, rowlive, owner, mine = _row_meta(sspec, g, n, axis)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        lab0 = jnp.where(rowlive, g.vt.ids[:, 1], UMAX)
        if warm:
            lab0 = jnp.where(rowlive, jnp.minimum(lab0, extra[0][0]), UMAX)

        def impl(rtgt, fwd, bwd):
            def cond(c):
                _, changed, it = c
                return changed & (it < max_iters)

            def step(c):
                lab, _, it = c
                cand = jnp.where(ok_e, lab[srcc], UMAX)
                pull = jnp.full((n_cap + 1,), UMAX, jnp.uint32).at[
                    dst].min(cand)
                nl = jnp.minimum(lab, pull[:n_cap])
                merged = jnp.full((n_cap + 1, 1), UMAX, jnp.uint32).at[
                    rtgt].min(fwd(nl[:, None]))
                back, okb = bwd(merged)
                nl = jnp.where(okb, back[:, 0], nl)
                ch = jax.lax.psum(jnp.any(mine & (nl < lab)).astype(
                    jnp.int32), axis) > 0
                return nl, ch, it + 1

            lab, _, it = jax.lax.while_loop(
                cond, step, (lab0, jnp.bool_(True), jnp.int32(0)))
            return lab, it

        lab, it = _owner_value_route(sspec, g, n, axis, a2a, owner, rowlive,
                                     frontier_budget, impl)
        out = jnp.where(rowlive, lab, UMAX)[None]
        return (out, it[None]) if warm else out

    in_specs = (P(axis),) + ((P(axis),) if warm else ())
    out_specs = (P(axis), P(axis)) if warm else P(axis)
    sharded = shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    return sharded


def make_sssp(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
              m_cap: int, max_iters: int = 64,
              frontier_budget: Optional[int] = None, warm: bool = False):
    """Build ``sssp(state, source_key) -> float32[n_shards, n_cap]`` —
    distributed Bellman-Ford (non-negative weights). Per round each shard
    relaxes its LOCAL edges (``min(dist[u] + w)`` — the same float op the
    single-shard reference applies), owners merge candidates with a
    min-scatter, and the merged distance is broadcast back to every copy.
    min is exact in floating point and the edge set is partitioned, so the
    per-round distances — and the round count — are BIT-EXACT against
    ``analytics.sssp``. Run on a vertex-synced state; INF = unreachable.

    ``warm`` adds a ``(n_shards, n_cap)`` float32 distance seed (a previous
    epoch's output verbatim — INF at then-dead rows) and returns
    ``(dist, iters_run)``. Valid for insert / weight-decrease deltas only
    (prev distances stay upper bounds); the min-relax fixed point is
    schedule-independent, so the warm run converges to the scratch answer."""
    n = int(mesh.shape[axis])
    INF = alg.INF

    def body(state, source_key, *extra):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, None)
        src, ok_e, dst = alg.csr_edges(snap)
        srcc = jnp.clip(src, 0, n_cap - 1)
        w_e = jnp.where(ok_e, snap.weight, 0.0)
        my, rowlive, owner, mine = _row_meta(sspec, g, n, axis)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)

        off0 = sort_mod.lookup(sspec, g.sort, source_key[None, :])[0]
        row = jnp.arange(n_cap, dtype=jnp.int32)
        dist0 = jnp.where((row == off0) & rowlive, 0.0, INF)
        if warm:
            dist0 = jnp.where(rowlive, jnp.minimum(dist0, extra[0][0]), INF)

        def impl(rtgt, fwd, bwd):
            def cond(c):
                _, changed, it = c
                return changed & (it < max_iters)

            def step(c):
                dist, _, it = c
                cand = jnp.where(ok_e, dist[srcc] + w_e, INF)
                relax = jnp.full((n_cap + 1,), INF).at[dst].min(cand)
                nd = jnp.minimum(dist, relax[:n_cap])
                merged = jnp.full((n_cap + 1, 1), INF).at[rtgt].min(
                    fwd(nd[:, None]))
                back, okb = bwd(merged)
                nd = jnp.where(okb, back[:, 0], nd)
                ch = jax.lax.psum(jnp.any(mine & (nd < dist)).astype(
                    jnp.int32), axis) > 0
                return nd, ch, it + 1

            dist, _, it = jax.lax.while_loop(
                cond, step, (dist0, jnp.bool_(True), jnp.int32(0)))
            return dist, it

        dist, it = _owner_value_route(sspec, g, n, axis, a2a, owner,
                                      rowlive, frontier_budget, impl)
        out = jnp.where(rowlive, dist, INF)[None]
        return (out, it[None]) if warm else out

    in_specs = (P(axis), P()) + ((P(axis),) if warm else ())
    out_specs = (P(axis), P(axis)) if warm else P(axis)
    sharded = shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    return sharded


def make_bfs_warm(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                  m_cap: int, max_iters: int = 32,
                  frontier_budget: Optional[int] = None):
    """Build ``bfs_warm(state, source_key, warm) -> (int32[n_shards,
    n_cap], iters)`` — distributed BFS as integer min-plus relaxation
    seeded from a previous epoch's depths (``-1`` = unknown). Unlike the
    level-synchronous ``make_bfs`` this converges from ANY upper-bound
    seed: prev depths are upper bounds after an insert-only delta, the
    min-relax fixed point is the true BFS distance, and depths beyond
    ``max_iters`` mask to -1 exactly like the scratch program's level cap.
    Stub-row depths are authoritative here too (the owner broadcast runs
    every round), so parity vs scratch holds at owner rows."""
    n = int(mesh.shape[axis])
    BIG = jnp.int32(1 << 30)

    def body(state, source_key, warm_vals):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, None)
        src, ok_e, dst = alg.csr_edges(snap)
        srcc = jnp.clip(src, 0, n_cap - 1)
        my, rowlive, owner, mine = _row_meta(sspec, g, n, axis)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)

        off0 = sort_mod.lookup(sspec, g.sort, source_key[None, :])[0]
        row = jnp.arange(n_cap, dtype=jnp.int32)
        w0 = warm_vals[0]
        d0 = jnp.where(rowlive & (w0 >= 0), w0, BIG)
        d0 = jnp.where((row == off0) & rowlive, 0, d0)

        def impl(rtgt, fwd, bwd):
            def cond(c):
                _, changed, it = c
                return changed & (it < 2 * max_iters + 2)

            def step(c):
                d, _, it = c
                cand = jnp.where(ok_e, jnp.minimum(d[srcc], BIG) + 1, BIG)
                relax = jnp.full((n_cap + 1,), BIG, jnp.int32).at[
                    dst].min(cand)
                nd = jnp.minimum(d, relax[:n_cap])
                merged = jnp.full((n_cap + 1, 1), BIG, jnp.int32).at[
                    rtgt].min(fwd(nd[:, None]))
                back, okb = bwd(merged)
                nd = jnp.where(okb, back[:, 0], nd)
                ch = jax.lax.psum(jnp.any(mine & (nd < d)).astype(
                    jnp.int32), axis) > 0
                return nd, ch, it + 1

            d, _, it = jax.lax.while_loop(
                cond, step, (d0, jnp.bool_(True), jnp.int32(0)))
            return d, it

        d, it = _owner_value_route(sspec, g, n, axis, a2a, owner, rowlive,
                                   frontier_budget, impl)
        out = jnp.where(rowlive & (d <= max_iters), d, -1)[None]
        return out, it[None]

    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis), P(), P(axis)),
                        out_specs=(P(axis), P(axis)), check_rep=False)
    return sharded


def make_degree_map(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                    m_cap: int):
    """Build ``deg(state) -> int32[n_shards, n_cap]`` — live out-degree at
    owner rows. Edges live in the source vertex's hash-owner shard (stub
    rows carry no adjacency), so the local CSR indptr diff at owner rows IS
    the full degree — no exchange needed."""
    n = int(mesh.shape[axis])

    def body(state):
        g = jax.tree.map(lambda x: x[0], state)
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, None)
        my, rowlive, owner, mine = _row_meta(sspec, g, n, axis)
        deg = snap.indptr[1:] - snap.indptr[:-1]
        return jnp.where(mine, deg, 0)[None]

    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis),),
                        out_specs=P(axis), check_rep=False)
    return sharded


def make_num_edges(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                   m_cap: int):
    """Build ``m(state) -> int32[n_shards]`` — per-shard live-edge
    partials; the store sums them host-side (scalar-result contract)."""
    def body(state):
        g = jax.tree.map(lambda x: x[0], state)
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, None)
        return snap.m.astype(jnp.int32)[None]

    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis),),
                        out_specs=P(axis), check_rep=False)
    return sharded


def make_bc(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
            m_cap: int, max_depth: int = 32,
            frontier_budget: Optional[int] = None):
    """Build ``bc(state, source_keys) -> float32[n_shards, n_cap]`` —
    distributed Brandes betweenness (unweighted, sampled sources; the
    distributed analogue of ``analytics.bc``). All sources run TOGETHER:
    depth/sigma/delta carry an S column per source, so each forward level /
    backward level is one value exchange regardless of S.

    Forward (per level): shards accumulate path counts along local edges,
    owners sum the per-shard partials, mark newly-reached rows, and
    broadcast (depth, sigma) back to every copy. Backward (per level):
    dependency contributions accumulate at local SOURCE rows (edges live in
    the source row's shard), owners sum, and delta is broadcast back.
    Owner-side sums add per-shard partials in slot order — deterministic,
    but a different association than the single-shard segment-sum, so
    compare with a small float tolerance (depths are exact)."""
    n = int(mesh.shape[axis])

    def body(state, source_keys):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        S = source_keys.shape[0]
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, None)
        src, ok_e, dst = alg.csr_edges(snap)
        srcc = jnp.clip(src, 0, n_cap - 1)
        dstc = jnp.clip(dst, 0, n_cap - 1)
        my, rowlive, owner, mine = _row_meta(sspec, g, n, axis)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)

        roffs = sort_mod.lookup(sspec, g.sort, source_keys)        # (S,)
        row = jnp.arange(n_cap, dtype=jnp.int32)
        is_src = (row[:, None] == roffs[None, :]) & (roffs[None, :] >= 0) \
            & rowlive[:, None]

        def impl(rtgt, fwd, bwd):
            depth0 = jnp.where(is_src, 0, -1)
            sigma0 = jnp.where(is_src, 1.0, 0.0)

            def sync_cols(vals):
                """Owner rows -> every copy (values already merged)."""
                back, okb = bwd(jnp.concatenate(
                    [vals, jnp.zeros((1, vals.shape[1]), vals.dtype)]))
                return back, okb

            def fwd_lvl(i, c):
                depth, sigma = c
                on_lvl = depth[srcc] == i
                add_l = jnp.zeros((n_cap + 1, S)).at[dst].add(
                    jnp.where(ok_e[:, None] & on_lvl,
                              sigma[srcc], 0.0))[:n_cap]
                add = jnp.zeros((n_cap + 1, S)).at[rtgt].add(
                    fwd(add_l))[:n_cap]
                newly = (add > 0) & (depth < 0)
                depth = jnp.where(newly, i + 1, depth)
                sigma = jnp.where(depth == i + 1, sigma + add, sigma)
                back, okb = sync_cols(jnp.concatenate(
                    [depth.astype(jnp.float32), sigma], axis=1))
                depth = jnp.where(okb[:, None],
                                  back[:, :S].astype(jnp.int32), depth)
                sigma = jnp.where(okb[:, None], back[:, S:], sigma)
                return depth, sigma

            depth, sigma = jax.lax.fori_loop(0, max_depth, fwd_lvl,
                                             (depth0, sigma0))

            du = depth[srcc]
            dv = depth[dstc]
            sig_ratio = sigma[srcc] / jnp.maximum(sigma[dstc], 1.0)

            def bwd_lvl(k, delta):
                lvl = max_depth - 1 - k
                onedge = ok_e[:, None] & (du == lvl) & (dv == lvl + 1)
                contrib = jnp.where(onedge,
                                    sig_ratio * (1.0 + delta[dstc]), 0.0)
                acc_l = jnp.zeros((n_cap, S)).at[srcc].add(contrib)
                acc = jnp.zeros((n_cap + 1, S)).at[rtgt].add(
                    fwd(acc_l))[:n_cap]
                delta = delta + acc
                back, okb = sync_cols(delta)
                return jnp.where(okb[:, None], back, delta)

            delta = jax.lax.fori_loop(0, max_depth, bwd_lvl,
                                      jnp.zeros((n_cap, S)))
            delta = jnp.where(is_src, 0.0, delta)
            return jnp.sum(delta, axis=1)

        vals = _owner_value_route(sspec, g, n, axis, a2a, owner, rowlive,
                                  frontier_budget, impl)
        return jnp.where(mine, vals, 0.0)[None]

    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis), P()),
                        out_specs=P(axis), check_rep=False)
    return sharded


def collect_owner_values(state: GraphState, values, n_shards: int) -> dict:
    """Host-side merge of a distributed analytics result: per-shard owner-row
    ``values`` (shape [n_shards, n_cap]) -> {vertex_id: value} over every
    live vertex (each vertex read from its single owner row). Vectorized —
    one mask + one zip, no per-row Python loop."""
    import numpy as np
    ids = np.asarray(state.vt.ids)
    dt = np.asarray(state.vt.del_time)
    vals = np.asarray(values)
    owner = np.asarray(shard_of_keys(
        jnp.asarray(ids.reshape(-1, 2)), n_shards)).reshape(ids.shape[:2])
    mask = (dt == 0) & (owner == np.arange(ids.shape[0])[:, None])
    vids = (ids[..., 0].astype(np.uint64) << np.uint64(32)) | \
        ids[..., 1].astype(np.uint64)
    return dict(zip(vids[mask].tolist(), vals[mask].tolist()))
