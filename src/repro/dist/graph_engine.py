"""Vertex-space sharding of RadixGraph over a mesh axis.

Partitioning: ``owner(key) = hash(key) % n_shards`` on the SOURCE vertex —
every edge (u, v, w) lives in u's shard, so one shard holds a vertex's whole
edge array and answers its queries locally (RapidStore-style decoupled
per-partition state). Undirected graphs insert both directions host-side,
exactly like the single-node ``RadixGraph``.

A batched update step under ``shard_map``:

1. each shard hashes its slice of the global op batch and ranks ops into
   per-owner buckets of ``cap`` slots. With the default
   ``capacity_factor=1.0``, ``cap`` equals the per-shard slice, so routing is
   lossless — a source shard can never overflow one owner's bucket with ops
   from its own slice;
2. one ``all_to_all`` exchanges the buckets. With ``pack=True`` the five
   payloads (src hi/lo, dst hi/lo, weight bits, validity) travel as a single
   uint32 word-matrix — one collective launch instead of four;
3. each shard applies its received ops with the SAME pure transition the
   single-shard path uses (``core.radixgraph.step_update_edges``), returning
   a per-shard ``dropped`` count (capacity refusals, never UB).

Queries (``make_khop_counts``) route identically and the owner's answers ride
a second all_to_all back to the asking shard, which restores request order.

All functions close over static specs, so a jitted engine step is one fused
SPMD program: route -> exchange -> apply, no host round-trips.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import edgepool as ep
from repro.core import radixgraph as rg
from repro.core import sort as sort_mod
from repro.core import vertex_table as vt_mod
from repro.core.radixgraph import GraphState
from repro.core.sort import SortSpec

__all__ = ["make_sharded_state", "make_apply_edges", "make_khop_counts",
           "shard_of_keys"]


def shard_of_keys(keys: jnp.ndarray, n_shards: int) -> jnp.ndarray:
    """Owner shard of each (..., 2) uint32 key — a cheap multiplicative hash
    with an xor-shift finalizer so dense ID ranges still spread evenly."""
    hi = keys[..., 0]
    lo = keys[..., 1]
    h = lo * jnp.uint32(0x9E3779B1) + hi * jnp.uint32(0x85EBCA77)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    return (h % jnp.uint32(n_shards)).astype(jnp.int32)


def make_sharded_state(sspec: SortSpec, pspec: ep.PoolSpec, n_shards: int,
                       n_per_shard: int) -> GraphState:
    """Fresh per-shard (SortState, VertexTable, EdgePool) pytrees stacked on
    a leading shard dim — the input/output carried by the engine's jitted
    step functions (shard dim maps onto the mesh axis)."""
    one = GraphState(
        sort=sort_mod.make_sort(sspec),
        vt=vt_mod.make_vertex_table(n_per_shard),
        pool=ep.make_edge_pool(pspec),
    )
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_shards,) + x.shape), one)


def _bucket_slots(owner: jnp.ndarray, valid: jnp.ndarray, cap: int):
    """Slot of each op in per-destination buckets of ``cap`` entries.

    Returns (slot, ok): ``slot = owner * cap + rank`` where rank is the op's
    stable order among same-owner ops; ``ok`` is False for invalid ops and
    bucket overflow (rank >= cap).
    """
    B = owner.shape[0]
    SENT = jnp.int32(0x7FFFFFFF)
    key = jnp.where(valid, owner, SENT)
    order = jnp.argsort(key, stable=True)
    so = key[order]
    idx = jnp.arange(B, dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), so[1:] != so[:-1]])
    start = jax.lax.cummax(jnp.where(first, idx, 0))
    rank_sorted = idx - start
    rank = jnp.zeros((B,), jnp.int32).at[order].set(rank_sorted)
    ok = valid & (rank < cap)
    return owner * cap + rank, ok


def _scatter_rows(x: jnp.ndarray, tgt: jnp.ndarray, n_rows: int, fill):
    out = jnp.full((n_rows,) + x.shape[1:], fill, x.dtype)
    return out.at[tgt].set(x, mode="drop")


def make_apply_edges(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                     pack: bool = True, capacity_factor: float = 1.0):
    """Build ``apply(state, src_keys, dst_keys, w, mask) -> (state, dropped)``.

    Inputs are GLOBAL batches: (B, 2) uint32 keys, (B,) f32 weights (0 =
    delete), (B,) bool mask, with B divisible by the shard count; ``state``
    is a ``make_sharded_state`` pytree. ``dropped`` is int32[n_shards] —
    per-shard refused ops (routing overflow when capacity_factor < 1, vertex
    table / pool exhaustion otherwise).
    """
    n = int(mesh.shape[axis])

    def body(state, sk, dk, w, mask):
        g = jax.tree.map(lambda x: x[0], state)
        Bl = sk.shape[0]
        cap = max(1, int(round(Bl * capacity_factor)))
        owner = shard_of_keys(sk, n)
        slot, ok = _bucket_slots(owner, mask, cap)
        route_drop = jnp.sum((mask & ~ok).astype(jnp.int32))
        NC = n * cap
        tgt = jnp.where(ok, slot, NC)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        if pack:
            payload = jnp.stack(
                [sk[:, 0], sk[:, 1], dk[:, 0], dk[:, 1],
                 jax.lax.bitcast_convert_type(w, jnp.uint32),
                 ok.astype(jnp.uint32)], axis=-1)            # (Bl, 6) u32
            buf = _scatter_rows(payload, tgt, NC, 0)
            r = a2a(buf.reshape(n, cap, 6)).reshape(NC, 6)
            rsk, rdk = r[:, 0:2], r[:, 2:4]
            rw = jax.lax.bitcast_convert_type(r[:, 4], jnp.float32)
            rmask = r[:, 5] == 1
        else:
            def xch(x, fill):
                buf = _scatter_rows(x, tgt, NC, fill)
                return a2a(buf.reshape((n, cap) + x.shape[1:])).reshape(
                    (NC,) + x.shape[1:])
            rsk = xch(sk, 0)
            rdk = xch(dk, 0)
            rw = xch(w, 0.0)
            rmask = xch(ok.astype(jnp.uint32), 0) == 1
        g, dropped = rg.step_update_edges(sspec, pspec, g, rsk, rdk, rw,
                                          rmask)
        return (jax.tree.map(lambda x: x[None], g),
                (dropped + route_drop)[None])

    sharded = shard_map(body, mesh=mesh,
                        in_specs=(P(axis), P(axis), P(axis), P(axis),
                                  P(axis)),
                        out_specs=(P(axis), P(axis)), check_rep=False)

    def apply_edges(state, src_keys, dst_keys, w, mask):
        B = src_keys.shape[0]
        assert B % n == 0, f"global op batch {B} not divisible by {n} shards"
        return sharded(state, src_keys, dst_keys, w, mask)

    return apply_edges


def make_khop_counts(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                     k: int = 1, read_ts: Optional[int] = None):
    """Build ``khop(state, query_keys) -> int32[Q]``: live (deduplicated)
    k-hop neighbourhood counts for arbitrary query keys, each answered by the
    key's owner shard (0 for absent vertices). Queries are routed with the
    same hash partition as updates; answers return on a second all_to_all in
    request order. Currently k == 1 (degree); deeper hops route frontiers
    recursively and are not implemented yet."""
    if k != 1:
        raise NotImplementedError("k-hop routing beyond 1 hop (degree) "
                                  "requires frontier re-routing rounds")
    n = int(mesh.shape[axis])

    def body(state, qk):
        g = jax.tree.map(lambda x: x[0], state)
        Ql = qk.shape[0]
        owner = shard_of_keys(qk, n)
        slot, _ = _bucket_slots(owner, jnp.ones((Ql,), bool), Ql)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        buf = _scatter_rows(qk, slot, n * Ql, 0)
        recv = a2a(buf.reshape(n, Ql, 2)).reshape(n * Ql, 2)
        # unrouted slots hold key 0: their answers are never read back
        cnt = rg.step_degree_counts(sspec, pspec, g, recv, read_ts=read_ts)
        back = a2a(cnt.reshape(n, Ql)).reshape(-1)
        return back[slot]

    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                        out_specs=P(axis), check_rep=False)

    def khop(state, query_keys):
        Q = query_keys.shape[0]
        assert Q % n == 0, f"query batch {Q} not divisible by {n} shards"
        return sharded(state, query_keys)

    return khop
