"""Vertex-space sharding of RadixGraph over a mesh axis.

Partitioning: ``owner(key) = hash(key) % n_shards`` on the SOURCE vertex —
every edge (u, v, w) lives in u's shard, so one shard holds a vertex's whole
edge array and answers its queries locally (RapidStore-style decoupled
per-partition state). Undirected graphs insert both directions host-side,
exactly like the single-node ``RadixGraph``.

A batched update step under ``shard_map``:

1. each shard hashes its slice of the global op batch and ranks ops into
   per-owner buckets of ``cap`` slots. With the default
   ``capacity_factor=1.0``, ``cap`` equals the per-shard slice, so routing is
   lossless — a source shard can never overflow one owner's bucket with ops
   from its own slice;
2. one ``all_to_all`` exchanges the buckets. With ``pack=True`` the five
   payloads (src hi/lo, dst hi/lo, weight bits, validity) travel as a single
   uint32 word-matrix — one collective launch instead of four;
3. each shard applies its received ops with the SAME pure transition the
   single-shard path uses (``core.radixgraph.step_update_edges``), returning
   a per-shard ``dropped`` count (capacity refusals, never UB).

Queries (``make_khop_counts``) route identically and the owner's answers ride
a second all_to_all back to the asking shard, which restores request order.

All functions close over static specs, so a jitted engine step is one fused
SPMD program: route -> exchange -> apply, no host round-trips.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import analytics as alg
from repro.core import edgepool as ep
from repro.core import radixgraph as rg
from repro.core import sort as sort_mod
from repro.core import vertex_table as vt_mod
from repro.core.radixgraph import GraphState
from repro.core.sort import SortSpec

__all__ = ["make_sharded_state", "make_apply_edges", "make_khop_counts",
           "make_sync_vertices", "make_snapshot", "make_bfs", "make_pagerank",
           "collect_owner_values", "shard_of_keys"]


def shard_of_keys(keys: jnp.ndarray, n_shards: int) -> jnp.ndarray:
    """Owner shard of each (..., 2) uint32 key — a cheap multiplicative hash
    with an xor-shift finalizer so dense ID ranges still spread evenly."""
    hi = keys[..., 0]
    lo = keys[..., 1]
    h = lo * jnp.uint32(0x9E3779B1) + hi * jnp.uint32(0x85EBCA77)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    return (h % jnp.uint32(n_shards)).astype(jnp.int32)


def make_sharded_state(sspec: SortSpec, pspec: ep.PoolSpec, n_shards: int,
                       n_per_shard: int) -> GraphState:
    """Fresh per-shard (SortState, VertexTable, EdgePool) pytrees stacked on
    a leading shard dim — the input/output carried by the engine's jitted
    step functions (shard dim maps onto the mesh axis)."""
    one = GraphState(
        sort=sort_mod.make_sort(sspec),
        vt=vt_mod.make_vertex_table(n_per_shard),
        pool=ep.make_edge_pool(pspec),
    )
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_shards,) + x.shape), one)


def _bucket_slots(owner: jnp.ndarray, valid: jnp.ndarray, cap: int):
    """Slot of each op in per-destination buckets of ``cap`` entries.

    Returns (slot, ok): ``slot = owner * cap + rank`` where rank is the op's
    stable order among same-owner ops; ``ok`` is False for invalid ops and
    bucket overflow (rank >= cap).
    """
    B = owner.shape[0]
    SENT = jnp.int32(0x7FFFFFFF)
    key = jnp.where(valid, owner, SENT)
    order = jnp.argsort(key, stable=True)
    so = key[order]
    idx = jnp.arange(B, dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), so[1:] != so[:-1]])
    start = jax.lax.cummax(jnp.where(first, idx, 0))
    rank_sorted = idx - start
    rank = jnp.zeros((B,), jnp.int32).at[order].set(rank_sorted)
    ok = valid & (rank < cap)
    return owner * cap + rank, ok


def _scatter_rows(x: jnp.ndarray, tgt: jnp.ndarray, n_rows: int, fill):
    out = jnp.full((n_rows,) + x.shape[1:], fill, x.dtype)
    return out.at[tgt].set(x, mode="drop")


def make_apply_edges(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                     pack: bool = True, capacity_factor: float = 1.0):
    """Build ``apply(state, src_keys, dst_keys, w, mask) -> (state, dropped)``.

    Inputs are GLOBAL batches: (B, 2) uint32 keys, (B,) f32 weights (0 =
    delete), (B,) bool mask, with B divisible by the shard count; ``state``
    is a ``make_sharded_state`` pytree. ``dropped`` is int32[n_shards] —
    per-shard refused ops (routing overflow when capacity_factor < 1, vertex
    table / pool exhaustion otherwise).
    """
    n = int(mesh.shape[axis])

    def body(state, sk, dk, w, mask):
        g = jax.tree.map(lambda x: x[0], state)
        Bl = sk.shape[0]
        cap = max(1, int(round(Bl * capacity_factor)))
        owner = shard_of_keys(sk, n)
        slot, ok = _bucket_slots(owner, mask, cap)
        route_drop = jnp.sum((mask & ~ok).astype(jnp.int32))
        NC = n * cap
        tgt = jnp.where(ok, slot, NC)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        if pack:
            payload = jnp.stack(
                [sk[:, 0], sk[:, 1], dk[:, 0], dk[:, 1],
                 jax.lax.bitcast_convert_type(w, jnp.uint32),
                 ok.astype(jnp.uint32)], axis=-1)            # (Bl, 6) u32
            buf = _scatter_rows(payload, tgt, NC, 0)
            r = a2a(buf.reshape(n, cap, 6)).reshape(NC, 6)
            rsk, rdk = r[:, 0:2], r[:, 2:4]
            rw = jax.lax.bitcast_convert_type(r[:, 4], jnp.float32)
            rmask = r[:, 5] == 1
        else:
            def xch(x, fill):
                buf = _scatter_rows(x, tgt, NC, fill)
                return a2a(buf.reshape((n, cap) + x.shape[1:])).reshape(
                    (NC,) + x.shape[1:])
            rsk = xch(sk, 0)
            rdk = xch(dk, 0)
            rw = xch(w, 0.0)
            rmask = xch(ok.astype(jnp.uint32), 0) == 1
        g, dropped = rg.step_update_edges(sspec, pspec, g, rsk, rdk, rw,
                                          rmask)
        return (jax.tree.map(lambda x: x[None], g),
                (dropped + route_drop)[None])

    sharded = shard_map(body, mesh=mesh,
                        in_specs=(P(axis), P(axis), P(axis), P(axis),
                                  P(axis)),
                        out_specs=(P(axis), P(axis)), check_rep=False)

    def apply_edges(state, src_keys, dst_keys, w, mask):
        B = src_keys.shape[0]
        assert B % n == 0, f"global op batch {B} not divisible by {n} shards"
        return sharded(state, src_keys, dst_keys, w, mask)

    return apply_edges


def make_khop_counts(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                     k: int = 1, read_ts: Optional[int] = None):
    """Build ``khop(state, query_keys) -> int32[Q]``: live (deduplicated)
    k-hop neighbourhood counts for arbitrary query keys, each answered by the
    key's owner shard (0 for absent vertices). Queries are routed with the
    same hash partition as updates; answers return on a second all_to_all in
    request order. Currently k == 1 (degree); deeper hops route frontiers
    recursively and are not implemented yet."""
    if k != 1:
        raise NotImplementedError("k-hop routing beyond 1 hop (degree) "
                                  "requires frontier re-routing rounds")
    n = int(mesh.shape[axis])

    def body(state, qk):
        g = jax.tree.map(lambda x: x[0], state)
        Ql = qk.shape[0]
        owner = shard_of_keys(qk, n)
        slot, _ = _bucket_slots(owner, jnp.ones((Ql,), bool), Ql)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        buf = _scatter_rows(qk, slot, n * Ql, 0)
        recv = a2a(buf.reshape(n, Ql, 2)).reshape(n * Ql, 2)
        # unrouted slots hold key 0: their answers are never read back
        cnt = rg.step_degree_counts(sspec, pspec, g, recv, read_ts=read_ts)
        back = a2a(cnt.reshape(n, Ql)).reshape(-1)
        return back[slot]

    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                        out_specs=P(axis), check_rep=False)

    def khop(state, query_keys):
        Q = query_keys.shape[0]
        assert Q % n == 0, f"query batch {Q} not divisible by {n} shards"
        return sharded(state, query_keys)

    return khop


# --------------------------------------------------------------------------
# distributed read path: per-shard CSR snapshots + level-synchronous
# analytics with frontier / inflow exchange over the mesh axis
#
# Edges live in the SOURCE vertex's shard, so a shard's CSR covers exactly
# its local rows; a vertex that only appears as a destination has stub rows
# (no edges) in source shards. ``make_sync_vertices`` registers every live
# row's ID at its hash-owner so that each vertex has exactly one OWNER row —
# the row analytics results are accumulated at and read from.
# --------------------------------------------------------------------------

def _row_meta(sspec, g: GraphState, n: int, axis: str):
    """Per-local-row metadata shared by the distributed analytics bodies."""
    my = jax.lax.axis_index(axis)
    rowlive = g.vt.del_time == 0
    owner = shard_of_keys(g.vt.ids, n)
    return my, rowlive, owner, rowlive & (owner == my)


def make_sync_vertices(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str):
    """Build ``sync(state) -> state``: every live local row's vertex ID is
    routed to its hash-owner shard and locate-or-inserted there, so each
    vertex gains an owner row even if it only ever appeared as an edge
    destination. Idempotent; run once before distributed analytics."""
    n = int(mesh.shape[axis])

    def body(state):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        rowlive = g.vt.del_time == 0
        owner = shard_of_keys(g.vt.ids, n)
        slot, ok = _bucket_slots(owner, rowlive, n_cap)
        NC = n * n_cap
        payload = jnp.stack([g.vt.ids[:, 0], g.vt.ids[:, 1],
                             ok.astype(jnp.uint32)], axis=-1)
        buf = _scatter_rows(payload, jnp.where(ok, slot, NC), NC, 0)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)
        r = a2a(buf.reshape(n, n_cap, 3)).reshape(NC, 3)
        st, vt, _, _ = vt_mod.ensure_vertices(sspec, g.sort, g.vt,
                                              r[:, 0:2], r[:, 2] == 1)
        g = GraphState(st, vt, g.pool)
        return jax.tree.map(lambda x: x[None], g)

    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis),),
                        out_specs=P(axis), check_rep=False)
    return sharded


def make_snapshot(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                  m_cap: int, read_ts: Optional[int] = None):
    """Build ``snap(state) -> GraphSnapshot`` with a leading shard dim: each
    shard builds the CSR of ITS slice of the edge set (dst column holds
    local row offsets) under shard_map — the distributed analogue of
    ``RadixGraph.snapshot``, one fused SPMD program, no host gather."""

    def body(state):
        g = jax.tree.map(lambda x: x[0], state)
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, read_ts)
        return jax.tree.map(lambda x: x[None], snap)

    return shard_map(body, mesh=mesh, in_specs=(P(axis),),
                     out_specs=P(axis), check_rep=False)


def make_bfs(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
             m_cap: int, max_iters: int = 32):
    """Build ``bfs(state, source_key) -> int32[n_shards, n_cap]`` — level-
    synchronous distributed BFS. Per level each shard expands its LOCAL CSR
    (``analytics.bfs_expand``), then newly-discovered row IDs are exchanged
    to their owner shards, which mark depth and seed the next frontier.
    Depths are authoritative at owner rows (-1 unreachable); stub rows may
    record the level their shard first saw the vertex. Run on a
    vertex-synced state (``make_sync_vertices``)."""
    n = int(mesh.shape[axis])

    def body(state, source_key):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        NC = n * n_cap
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, None)
        edges = alg.csr_edges(snap)   # loop-invariant: built once, not per level
        my, rowlive, owner, _mine = _row_meta(sspec, g, n, axis)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)

        off0 = sort_mod.lookup(sspec, g.sort, source_key[None, :])[0]
        row = jnp.arange(n_cap, dtype=jnp.int32)
        depth0 = jnp.where(row == off0, 0, -1)
        frontier0 = (row == off0) & rowlive
        go0 = jax.lax.psum(jnp.any(frontier0).astype(jnp.int32), axis) > 0

        def cond(c):
            _, _, it, go = c
            return go & (it < max_iters)

        def lvl(c):
            depth, frontier, it, _ = c
            new_local = alg.bfs_expand(snap, frontier, edges) & (depth < 0)
            # stub rows are marked locally (each row notifies at most once);
            # owner rows are marked via the exchange below, which also
            # dedups discoveries arriving from several shards at once
            slot, ok = _bucket_slots(owner, new_local, n_cap)
            payload = jnp.stack([g.vt.ids[:, 0], g.vt.ids[:, 1],
                                 ok.astype(jnp.uint32)], axis=-1)
            buf = _scatter_rows(payload, jnp.where(ok, slot, NC), NC, 0)
            r = a2a(buf.reshape(n, n_cap, 3)).reshape(NC, 3)
            roff = sort_mod.lookup(sspec, g.sort, r[:, 0:2])
            seen = (r[:, 2] == 1) & (roff >= 0)
            hit = jnp.zeros((n_cap + 1,), bool).at[
                jnp.where(seen, roff, n_cap)].max(True)[:n_cap]
            depth = jnp.where(new_local & (owner != my), it + 1, depth)
            nxt = hit & (depth < 0)
            depth = jnp.where(nxt, it + 1, depth)
            go = jax.lax.psum(jnp.any(nxt).astype(jnp.int32), axis) > 0
            return depth, nxt, it + 1, go

        depth, _, _, _ = jax.lax.while_loop(
            cond, lvl, (depth0, frontier0, jnp.int32(0), go0))
        return depth[None]

    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis), P()),
                        out_specs=P(axis), check_rep=False)
    return sharded


def make_pagerank(sspec: SortSpec, pspec: ep.PoolSpec, mesh, axis: str,
                  m_cap: int, iters: int = 20, damping: float = 0.85):
    """Build ``pr(state) -> float32[n_shards, n_cap]`` — distributed
    PageRank. Ranks live at owner rows; per iteration each shard scatters
    contributions along its local CSR (``analytics.pagerank_scatter``) and
    routes every live row's accumulated inflow back to the row's owner over
    one all_to_all (the combine phase). Dangling mass and the active count
    are psums over owner rows. Run on a vertex-synced state."""
    n = int(mesh.shape[axis])

    def body(state):
        g = jax.tree.map(lambda x: x[0], state)
        n_cap = g.vt.del_time.shape[0]
        NC = n * n_cap
        snap = rg.step_snapshot(sspec, pspec, m_cap, g, None)
        edges = alg.csr_edges(snap)   # loop-invariant: built once, not per iter
        my, rowlive, owner, mine = _row_meta(sspec, g, n, axis)
        deg = (snap.indptr[1:] - snap.indptr[:-1]).astype(jnp.float32)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                split_axis=0, concat_axis=0)

        n_act = jnp.maximum(jax.lax.psum(
            jnp.sum(mine.astype(jnp.float32)), axis), 1.0)
        pr0 = jnp.where(mine, 1.0 / n_act, 0.0)

        # the inflow routing is data-independent (every live row -> its
        # owner): exchange the keys once, reuse the slots every iteration
        slot, ok = _bucket_slots(owner, rowlive, n_cap)
        keybuf = _scatter_rows(
            jnp.stack([g.vt.ids[:, 0], g.vt.ids[:, 1],
                       ok.astype(jnp.uint32)], axis=-1),
            jnp.where(ok, slot, NC), NC, 0)
        rk = a2a(keybuf.reshape(n, n_cap, 3)).reshape(NC, 3)
        roff = sort_mod.lookup(sspec, g.sort, rk[:, 0:2])
        rtgt = jnp.where((rk[:, 2] == 1) & (roff >= 0), roff, n_cap)

        def step(pr, _):
            contrib = alg.pagerank_contrib(snap, pr)
            local_in = alg.pagerank_scatter(snap, contrib, edges)
            vbuf = _scatter_rows(local_in, jnp.where(ok, slot, NC), NC, 0.0)
            rv = a2a(vbuf.reshape(n, n_cap)).reshape(NC)
            inflow = jnp.zeros((n_cap + 1,)).at[rtgt].add(rv)[:n_cap]
            dangling = jax.lax.psum(
                jnp.sum(jnp.where(mine & (deg == 0), pr, 0.0)), axis)
            pr = jnp.where(mine, (1 - damping) / n_act +
                           damping * (inflow + dangling / n_act), 0.0)
            return pr, None

        pr, _ = jax.lax.scan(step, pr0, None, length=iters)
        return pr[None]

    sharded = shard_map(body, mesh=mesh, in_specs=(P(axis),),
                        out_specs=P(axis), check_rep=False)
    return sharded


def collect_owner_values(state: GraphState, values, n_shards: int) -> dict:
    """Host-side merge of a distributed analytics result: per-shard owner-row
    ``values`` (shape [n_shards, n_cap]) -> {vertex_id: value} over every
    live vertex (each vertex read from its single owner row). Vectorized —
    one mask + one zip, no per-row Python loop."""
    import numpy as np
    ids = np.asarray(state.vt.ids)
    dt = np.asarray(state.vt.del_time)
    vals = np.asarray(values)
    owner = np.asarray(shard_of_keys(
        jnp.asarray(ids.reshape(-1, 2)), n_shards)).reshape(ids.shape[:2])
    mask = (dt == 0) & (owner == np.arange(ids.shape[0])[:, None])
    vids = (ids[..., 0].astype(np.uint64) << np.uint64(32)) | \
        ids[..., 1].astype(np.uint64)
    return dict(zip(vids[mask].tolist(), vals[mask]))
