"""Distribution layer: sharding planner, gradient compression, and the
vertex-space-sharded RadixGraph engine.

Three independent modules:

* :mod:`repro.dist.sharding` — rule-based partition planner mapping logical
  axis names to mesh axes (consumed by ``models/lm.py`` and the launchers);
* :mod:`repro.dist.compress` — int8 symmetric-scale gradient compression
  with a half-ULP error bound (error-feedback friendly);
* :mod:`repro.dist.graph_engine` — the paper's RadixGraph scaled over a
  device mesh by vertex-space sharding (routed batched edge ops,
  owner-answered queries) plus the distributed read path: per-shard CSR
  snapshots and level-synchronous BFS / PageRank with frontier / inflow
  exchange over the mesh axis.
"""
from . import compress, graph_engine, sharding  # noqa: F401

__all__ = ["sharding", "compress", "graph_engine"]
