"""Rule-based partition planner.

Model code annotates every tensor dim with a *logical* axis name ("fsdp",
"tp", "batch", ...); a :class:`ShardingRules` table maps logical names to
mesh axes. :func:`spec_for` resolves one tensor's logical annotation against
a concrete mesh into a ``PartitionSpec`` with two safety rails:

* **divisibility fallback** — a dim that is not divisible by the product of
  its candidate mesh-axis sizes is replicated instead (never an XLA error;
  e.g. 12 heads on a 16-way model axis, batch=1 long-context serving);
* **no mesh axis twice** — within one tensor, a mesh axis already consumed
  by an earlier dim is dropped from later candidates (e.g. "tp" and "tp_in"
  both map to "model": square weights shard only the first dim).

Rule entries may name axes missing from the current mesh (the planner
filters by presence), so the same rule tables drive the 2x16x16 production
mesh and a 1-device debug mesh.

``set_rules`` pushes an active (rules, mesh) context consumed by
:func:`constrain` — the logical-axis analogue of
``with_sharding_constraint`` used inside model code — and inspected by
dispatch heuristics (``models/lm.moe_apply``).
"""
from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple, \
    Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "TRAIN_RULES", "SERVE_RULES", "MOE_SERVE_RULES",
           "VARIANTS", "spec_for", "param_partition_specs", "set_rules",
           "constrain"]

MeshAxes = Union[None, str, Tuple[str, ...]]


class ShardingRules(dict):
    """logical axis name -> mesh axis name | tuple of names | None."""


# Training: ZeRO/FSDP over the (pod, data) axes + Megatron TP over "model".
TRAIN_RULES = ShardingRules({
    "layers": None,          # lax.scan dim — never sharded
    "unit": None,            # hybrid block-pattern dim
    "embed": None,           # norm scales et al. — replicated
    "batch": ("pod", "data"),
    "act_seq": None,         # activation sequence dim
    "cache_seq": None,       # KV-cache sequence dim
    "fsdp": ("pod", "data"),
    "tp": "model",
    "tp_in": "model",        # second TP dim of square weights -> dropped
    "kv_tp": "model",
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    "experts": None,         # dense MoE dispatch under FSDP training
})

# Serving: weights replicated over the batch axes (no FSDP all-gathers on
# the latency path), pure TP over "model", requests sharded on (pod, data).
SERVE_RULES = ShardingRules({**TRAIN_RULES, "fsdp": None})

# MoE serving: expert parallelism over the batch axes; fsdp=None + experts
# set is the signature models/lm.moe_apply keys the all-to-all dispatch on.
MOE_SERVE_RULES = ShardingRules({**SERVE_RULES, "experts": ("pod", "data")})

# Named planner/config deltas for ablation dry-runs (launch/dryrun
# --variant, benchmarks/roofline): (rule overrides, ModelConfig overrides).
VARIANTS: Dict[str, Tuple[Dict[str, MeshAxes], Dict[str, Any]]] = {
    "baseline": ({}, {}),
    "no_fsdp": ({"fsdp": None}, {}),
    "no_tp": ({"tp": None, "tp_in": None, "kv_tp": None, "heads": None,
               "kv_heads": None, "vocab": None}, {}),
    "expert_parallel": ({"fsdp": None, "experts": ("pod", "data")}, {}),
    "seq_parallel": ({"act_seq": "model"}, {}),
    "no_remat": ({}, {"remat": False}),
}


def _candidate_axes(entry: MeshAxes, mesh_shape, used) -> Tuple[str, ...]:
    if entry is None:
        return ()
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    return tuple(a for a in axes if a in mesh_shape and a not in used)


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             rules: ShardingRules, mesh) -> P:
    """Resolve one tensor's logical annotation into a PartitionSpec.

    ``axes`` is parallel to ``shape`` (None entries and any trailing dims are
    replicated). Resolution is left-to-right; each rule entry is applied
    all-or-nothing after filtering to axes present in the mesh.
    """
    mesh_shape = dict(mesh.shape)
    used: set = set()
    entries: List[MeshAxes] = []
    for dim, name in zip(shape, axes):
        entry: MeshAxes = None
        if name is not None:
            cand = _candidate_axes(rules.get(name), mesh_shape, used)
            if cand:
                n = math.prod(mesh_shape[a] for a in cand)
                if n > 0 and dim % n == 0:
                    used.update(cand)
                    entry = cand[0] if len(cand) == 1 else cand
        entries.append(entry)
    return P(*entries)


def param_partition_specs(shapes, logical, rules: ShardingRules, mesh):
    """Map parallel (param shapes, logical annotations) pytrees to a pytree
    of PartitionSpecs. ``shapes`` leaves are arrays/ShapeDtypeStructs;
    ``logical`` mirrors the container structure with axis-name tuples at the
    leaf positions (tuples are containers to jax.tree, hence the explicit
    walk)."""
    def rec(s, lg):
        if hasattr(s, "shape"):
            return spec_for(s.shape, tuple(lg), rules, mesh)
        if isinstance(s, dict):
            return {k: rec(v, lg[k]) for k, v in s.items()}
        if isinstance(s, (list, tuple)):
            out = [rec(a, b) for a, b in zip(s, lg)]
            return type(s)(out) if not hasattr(s, "_fields") \
                else type(s)(*out)
        raise TypeError(f"unsupported params node: {type(s)!r}")
    return rec(shapes, logical)


class _RulesContext(NamedTuple):
    rules: ShardingRules
    mesh: Any


_ACTIVE: List[_RulesContext] = []


@contextlib.contextmanager
def set_rules(rules: ShardingRules, mesh=None):
    """Activate (rules, mesh) for ``constrain`` and dispatch heuristics."""
    ctx = _RulesContext(ShardingRules(rules), mesh)
    _ACTIVE.append(ctx)
    try:
        yield ctx
    finally:
        _ACTIVE.pop()


def constrain(x, *axes: Optional[str]):
    """Constrain ``x`` to the active context's resolution of the logical
    ``axes``. No-op outside a ``set_rules`` context (keeps model code usable
    without a mesh, e.g. single-device tests)."""
    if not _ACTIVE:
        return x
    ctx = _ACTIVE[-1]
    if ctx.mesh is None:
        return x
    spec = spec_for(x.shape, axes, ctx.rules, ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))
