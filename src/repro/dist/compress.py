"""Gradient compression: symmetric-scale int8 quantization.

``q = round(x / s)`` with ``s = max|x| / 127`` maps the tensor onto
[-127, 127] with reconstruction error at most ``s / 2`` per element (half a
quantization step — round-to-nearest never exceeds it, and the scale is
chosen so no value clips). The bounded, zero-mean-ish error makes the codec
safe for error-feedback accumulation: feeding the residual
``x - dequantize(quantize(x))`` back into the next step telescopes, so the
accumulated compressed signal tracks the accumulated true signal to within
one residual. Hook :func:`error_feedback` into
``train.make_train_step(grad_transform=...)`` to compress the gradient
all-reduce 4x (fp32 -> int8 + one scalar).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "error_feedback"]


def quantize_int8(x: jnp.ndarray,
                  axis: Optional[int] = None) -> Tuple[jnp.ndarray,
                                                       jnp.ndarray]:
    """Returns (q int8, scale f32). ``axis=None`` uses one tensor-wide scale;
    an int axis computes per-slice scales along that axis (kept broadcastable
    so ``dequantize_int8(q, s)`` works unchanged)."""
    ax = None if axis is None else (axis,)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=ax,
                   keepdims=axis is not None)
    s = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.round(x.astype(jnp.float32) / s)
    return jnp.clip(q, -127, 127).astype(jnp.int8), s


def dequantize_int8(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * s


def error_feedback(g: jnp.ndarray, residual: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One error-feedback step: compress ``g + residual``, return the
    decompressed signal to apply and the new residual to carry."""
    corrected = g + residual
    deq = dequantize_int8(*quantize_int8(corrected))
    return deq, corrected - deq
