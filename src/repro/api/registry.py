"""The distributed-analytics registry: algorithm name -> how every backend
runs it.

Each entry pairs the SHARD-LOCAL reference implementation (the single-CSR
algorithms in ``analytics.algorithms`` — also the per-shard phases of the
distributed loops) with the MESH COMBINE factory from ``dist.graph_engine``
that stitches those phases over the shard axis. A backend never dispatches
on algorithm names: ``LocalStore`` runs ``spec.single`` on its snapshot,
``ShardedStore`` builds (and jit-caches) ``spec.make_dist`` — so adding an
algorithm, or a whole new backend, is a registration, not a rewrite.

Result kinds:

* ``per_vertex`` — a value per live vertex; stores normalize to
  ``{vertex_id: value}`` so answers are backend-independent;
* ``per_query``  — an array aligned with the queried ID batch;
* ``scalar``     — one number for the whole graph.

``canonical_single`` post-processes the single-shard result into the
backend-independent form (e.g. WCC's row-offset labels become the
component's minimum vertex ID — exactly what the distributed loop
propagates), so cross-backend parity is exact equality, not heuristics.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro import analytics as A
from repro.analytics import incremental as inc
from repro.core.keys import unpack_keys
from repro.core.status import Reason
from repro.dist import graph_engine as ge

__all__ = ["AnalyticsSpec", "ANALYTICS", "register_analytics",
           "analytics_spec", "available_analytics"]


@dataclasses.dataclass(frozen=True)
class AnalyticsSpec:
    """How one named algorithm runs on every backend.

    ``single(snap, *dyn, **static)`` answers on a single CSR snapshot;
    ``make_dist(sspec, pspec, mesh, axis, m_cap, frontier_budget,
    **static)`` builds the mesh program (``None`` = no distributed form
    yet — the sharded backend raises with a pointer here).

    ``dyn`` lists (param_name, kind) resolved per backend before the call:
    ``'id'`` — one vertex ID -> int32 offset (single) / packed key (dist);
    ``'ids'`` — an ID array -> offsets / packed keys.
    ``absent`` is the per-vertex fill when a required ``'id'`` param names
    a vertex the graph has never seen (dist loops yield it naturally; the
    single path short-circuits to it).

    The incremental engine hangs off two optional phases:

    ``advance(prev_raw, delta, csr_prev, csr_cur, dyn, params)`` advances
    the previous epoch's RAW per-row values (canonical form — what the
    store keeps in ``AnalyticsResult.raw``) over one ``EpochDelta`` on
    host ``HostCsr`` views, returning ``(raw, iters)`` or ``None`` to
    force the scratch fallback. ``make_dist_warm(sspec, pspec, mesh,
    axis, m_cap, budget, **static)`` builds the mesh program seeded from
    the previous per-shard raw values (an extra trailing ``(n_shards,
    n_cap)`` input) returning ``(vals, per_shard_iters)``. Either may be
    absent — the store then answers from scratch and says so in
    ``AnalyticsResult.mode``.

    ``warm_guard(flags)`` (flags = ``epoch_delta.merged_flags``) returns
    a fallback reason when the delta breaks the warm program's
    monotonicity precondition — the device loops can't self-check the
    way the host advances do, so the store gates before dispatching.
    """

    name: str
    single: Callable
    make_dist: Optional[Callable]
    dyn: Tuple[Tuple[str, str], ...] = ()
    result: str = "per_vertex"
    absent: Optional[float] = None
    canonical_single: Optional[Callable] = None
    advance: Optional[Callable] = None
    make_dist_warm: Optional[Callable] = None
    warm_guard: Optional[Callable] = None


ANALYTICS: Dict[str, AnalyticsSpec] = {}


def register_analytics(spec: AnalyticsSpec) -> AnalyticsSpec:
    """Register (or override) an algorithm for every GraphStore backend."""
    ANALYTICS[spec.name] = spec
    return spec


def analytics_spec(name: str) -> AnalyticsSpec:
    if name not in ANALYTICS:
        raise KeyError(f"unknown analytics op {name!r}; registered: "
                       f"{sorted(ANALYTICS)} (register_analytics adds more)")
    return ANALYTICS[name]


def available_analytics(distributed: Optional[bool] = None):
    """Registered names; ``distributed=True`` filters to mesh-capable."""
    return sorted(n for n, s in ANALYTICS.items()
                  if distributed is None
                  or (s.make_dist is not None) == distributed)


def _wcc_canonical(vals: np.ndarray, snap) -> np.ndarray:
    """Row-offset component labels -> per-row minimum member vertex ID
    (uint64) — the canonical labeling the distributed loop propagates."""
    lab = np.asarray(vals)
    active = np.asarray(snap.active)
    vid = unpack_keys(np.asarray(snap.ids))
    out = np.zeros(lab.shape, np.uint64)
    live = active & (lab >= 0)
    labs = lab[live]
    if labs.size:
        order = np.argsort(labs, kind="stable")
        min_of = {}
        for l, v in zip(labs[order].tolist(), vid[live][order].tolist()):
            if l not in min_of or v < min_of[l]:
                min_of[l] = v
        out[live] = np.array([min_of[l] for l in labs.tolist()], np.uint64)
    return out


register_analytics(AnalyticsSpec(
    name="bfs",
    single=lambda snap, source, max_iters=32:
        A.bfs(snap, source, max_iters=max_iters),
    make_dist=lambda sspec, pspec, mesh, axis, m_cap, budget, max_iters=32:
        ge.make_bfs(sspec, pspec, mesh, axis, m_cap, max_iters=max_iters,
                    frontier_budget=budget),
    advance=lambda prev, delta, cp, cc, dyn, params:
        inc.advance_bfs(prev, delta, cc, int(dyn[0]),
                        int(params.get("max_iters", 32))),
    make_dist_warm=lambda sspec, pspec, mesh, axis, m_cap, budget,
    max_iters=32:
        ge.make_bfs_warm(sspec, pspec, mesh, axis, m_cap,
                         max_iters=max_iters, frontier_budget=budget),
    warm_guard=lambda f: Reason.DELETES if f["has_deletes"] else None,
    dyn=(("source", "id"),), absent=-1))


def _pagerank_single(snap, iters=20, damping=0.85, tol=None):
    """``tol=None`` keeps the fixed-iteration reference (bit-identical to
    the pre-incremental entry); with a tolerance the loop runs to
    convergence (``iters`` becomes the cap, floored at 100 so default
    calls actually converge) and returns ``(pr, iters_run)``."""
    if tol is None:
        return A.pagerank(snap, iters=iters, damping=damping)
    import jax.numpy as jnp
    pr0 = jnp.zeros((snap.active.shape[0],), jnp.float32)
    return inc.pagerank_converge(snap, pr0, iters=max(int(iters), 100),
                                 damping=float(damping), tol=float(tol),
                                 uniform0=True)


def _pagerank_advance(prev, delta, cp, cc, dyn, params):
    tol = params.get("tol")
    if tol is None:
        return None     # fixed-iteration ranks are path-dependent: scratch
    return inc.advance_pagerank(prev, cc,
                                damping=float(params.get("damping", 0.85)),
                                tol=float(tol))


register_analytics(AnalyticsSpec(
    name="pagerank",
    single=_pagerank_single,
    make_dist=lambda sspec, pspec, mesh, axis, m_cap, budget, iters=20,
    damping=0.85, tol=None:
        ge.make_pagerank(sspec, pspec, mesh, axis, m_cap,
                         iters=(iters if tol is None
                                else max(int(iters), 100)),
                         damping=damping, frontier_budget=budget,
                         tol=tol),
    advance=_pagerank_advance,
    make_dist_warm=lambda sspec, pspec, mesh, axis, m_cap, budget,
    iters=20, damping=0.85, tol=None:
        None if tol is None else
        ge.make_pagerank(sspec, pspec, mesh, axis, m_cap,
                         iters=max(int(iters), 100), damping=damping,
                         frontier_budget=budget, tol=float(tol),
                         warm=True)))

register_analytics(AnalyticsSpec(
    name="wcc",
    single=lambda snap, max_iters=64: A.wcc(snap, max_iters=max_iters),
    make_dist=lambda sspec, pspec, mesh, axis, m_cap, budget, max_iters=64:
        ge.make_wcc(sspec, pspec, mesh, axis, m_cap, max_iters=max_iters,
                    frontier_budget=budget),
    advance=lambda prev, delta, cp, cc, dyn, params:
        inc.advance_wcc(prev, delta, cc),
    make_dist_warm=lambda sspec, pspec, mesh, axis, m_cap, budget,
    max_iters=64:
        ge.make_wcc(sspec, pspec, mesh, axis, m_cap, max_iters=max_iters,
                    frontier_budget=budget, warm=True),
    warm_guard=lambda f: Reason.DELETES if f["has_deletes"] else None,
    canonical_single=_wcc_canonical))

register_analytics(AnalyticsSpec(
    name="sssp",
    single=lambda snap, source, max_iters=64:
        A.sssp(snap, source, max_iters=max_iters),
    make_dist=lambda sspec, pspec, mesh, axis, m_cap, budget, max_iters=64:
        ge.make_sssp(sspec, pspec, mesh, axis, m_cap, max_iters=max_iters,
                     frontier_budget=budget),
    advance=lambda prev, delta, cp, cc, dyn, params:
        inc.advance_sssp(prev, delta, cc, int(dyn[0]),
                         int(params.get("max_iters", 64))),
    make_dist_warm=lambda sspec, pspec, mesh, axis, m_cap, budget,
    max_iters=64:
        ge.make_sssp(sspec, pspec, mesh, axis, m_cap, max_iters=max_iters,
                     frontier_budget=budget, warm=True),
    warm_guard=lambda f: (Reason.DELETES if f["has_deletes"] else
                          Reason.WEIGHT_INCREASE
                          if f["has_weight_increase"] else None),
    dyn=(("source", "id"),), absent=float(A.INF)))

register_analytics(AnalyticsSpec(
    name="bc",
    single=lambda snap, sources, max_depth=32:
        A.bc(snap, sources, max_depth=max_depth),
    make_dist=lambda sspec, pspec, mesh, axis, m_cap, budget, max_depth=32:
        ge.make_bc(sspec, pspec, mesh, axis, m_cap, max_depth=max_depth,
                   frontier_budget=budget),
    dyn=(("sources", "ids"),)))

register_analytics(AnalyticsSpec(
    name="khop",
    single=lambda snap, sources, k=2: A.khop(snap, sources, k=k),
    make_dist=lambda sspec, pspec, mesh, axis, m_cap, budget, k=2:
        ge.make_khop_counts(sspec, pspec, mesh, axis, k=k, m_cap=m_cap,
                            frontier_budget=budget),
    dyn=(("sources", "ids"),), result="per_query"))

register_analytics(AnalyticsSpec(
    name="triangle_count",
    single=lambda snap: A.triangle_count(snap),
    make_dist=None,     # intersection needs remote adjacency; future entry
    result="scalar"))

register_analytics(AnalyticsSpec(
    name="degree_map",
    single=lambda snap: snap.indptr[1:] - snap.indptr[:-1],
    make_dist=lambda sspec, pspec, mesh, axis, m_cap, budget:
        ge.make_degree_map(sspec, pspec, mesh, axis, m_cap),
    advance=lambda prev, delta, cp, cc, dyn, params:
        inc.advance_degree(prev, delta, cp, cc)))

register_analytics(AnalyticsSpec(
    name="num_edges",
    single=lambda snap: snap.m,
    make_dist=lambda sspec, pspec, mesh, axis, m_cap, budget:
        ge.make_num_edges(sspec, pspec, mesh, axis, m_cap),
    advance=lambda prev, delta, cp, cc, dyn, params:
        inc.advance_num_edges(prev, delta),
    result="scalar"))
