"""Typed operation IR of the ``GraphStore`` front door.

Three value kinds cover everything a storage backend is asked to do —
mutate (``OpBatch``), look up (``ReadOp``), and run a registered algorithm
(``AnalyticsOp``). Ops are host-side descriptions carrying exact (ragged)
numpy arrays of vertex IDs; the FIXED-SHAPE PADDING RULE lives in the
backends: every store pads a batch to its static ``batch`` width with
masked-off rows before touching a jitted program, so differently-sized
submissions reuse one compile cache (the same discipline ``RadixGraph``
and the sharded engine already apply internally).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Tuple

import numpy as np

__all__ = ["OpBatch", "ReadOp", "AnalyticsOp", "ApplyResult",
           "AnalyticsResult", "UnsupportedOpError"]

_OP_KINDS = ("edges", "add_vertices", "delete_vertices")


class UnsupportedOpError(NotImplementedError):
    """A structurally valid ``OpBatch`` the target backend cannot route.

    Carries the op ``kind`` and the refusing ``backend`` so admission
    layers (the query service) can surface a typed rejection instead of
    crashing the write loop. Subclasses ``NotImplementedError`` so legacy
    ``except NotImplementedError`` callers keep working."""

    def __init__(self, kind: str, backend: str, detail: str = ""):
        self.kind = kind
        self.backend = backend
        msg = f"op kind {kind!r} is not supported by the {backend!r} backend"
        super().__init__(msg + (f": {detail}" if detail else ""))
_READ_KINDS = ("lookup", "degree", "neighbors", "snapshot", "num_vertices",
               "num_edges")


@dataclasses.dataclass(frozen=True)
class OpBatch:
    """One batch of graph mutations.

    ``kind='edges'``: parallel ``src``/``dst`` uint64 ID arrays plus a
    float32 ``weight`` per op — ``0.0`` is the paper's NULL tombstone
    (delete), ``None`` means all-ones inserts. Order is the operation
    order (last-writer-wins within a batch, exactly like the engine).

    ``kind='add_vertices'`` / ``'delete_vertices'``: ``ids`` only.
    """

    kind: str = "edges"
    src: Optional[np.ndarray] = None
    dst: Optional[np.ndarray] = None
    weight: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.kind not in _OP_KINDS:
            raise ValueError(f"OpBatch kind {self.kind!r} not in {_OP_KINDS}")
        if self.kind == "edges":
            if self.src is None or self.dst is None:
                raise ValueError("edges batch needs src and dst")
            src = np.asarray(self.src, np.uint64)
            dst = np.asarray(self.dst, np.uint64)
            if src.shape != dst.shape:
                raise ValueError("src/dst length mismatch")
            w = (np.ones(len(src), np.float32) if self.weight is None
                 else np.asarray(self.weight, np.float32))
            if w.shape != src.shape:
                raise ValueError("weight length mismatch")
            object.__setattr__(self, "src", src)
            object.__setattr__(self, "dst", dst)
            object.__setattr__(self, "weight", w)
        else:
            if self.ids is None:
                raise ValueError(f"{self.kind} batch needs ids")
            object.__setattr__(self, "ids",
                              np.asarray(self.ids, np.uint64))

    @staticmethod
    def edges(src, dst, weight=None) -> "OpBatch":
        return OpBatch(kind="edges", src=src, dst=dst, weight=weight)

    @staticmethod
    def add_vertices(ids) -> "OpBatch":
        return OpBatch(kind="add_vertices", ids=ids)

    @staticmethod
    def delete_vertices(ids) -> "OpBatch":
        return OpBatch(kind="delete_vertices", ids=ids)

    def __len__(self) -> int:
        return len(self.src if self.kind == "edges" else self.ids)


@dataclasses.dataclass(frozen=True)
class ReadOp:
    """One lookup-class read.

    kinds (cross-backend semantics — identical answers on every backend):

    * ``lookup``       -> bool[len(ids)]: vertex currently live? (row
                          offsets are backend-private, so the portable
                          answer is presence);
    * ``degree``       -> int32[len(ids)] live out-degree (0 if absent);
    * ``neighbors``    -> list of (neighbor_ids uint64[], weights f32[]);
    * ``num_vertices`` / ``num_edges`` -> int;
    * ``snapshot``     -> the backend-NATIVE CSR artifact (single
                          ``GraphSnapshot`` locally, shard-stacked on the
                          sharded backend) — the one deliberately
                          non-portable read, for analytics plumbing.
    """

    kind: str
    ids: Optional[np.ndarray] = None
    width: Optional[int] = None     # neighbors: max returned per vertex

    def __post_init__(self):
        if self.kind not in _READ_KINDS:
            raise ValueError(f"ReadOp kind {self.kind!r} not in "
                             f"{_READ_KINDS}")
        if self.kind in ("lookup", "degree", "neighbors"):
            if self.ids is None:
                raise ValueError(f"{self.kind} read needs ids")
            object.__setattr__(self, "ids", np.asarray(self.ids, np.uint64))


def _freeze(v) -> Any:
    if isinstance(v, np.ndarray):
        return ("ndarray",) + tuple(v.reshape(-1).tolist()) + (v.shape,)
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    return v


@dataclasses.dataclass(frozen=True)
class AnalyticsOp:
    """A registered algorithm by name plus its parameters.

    ``params`` mixes static knobs (``iters``, ``max_iters``, ``k``,
    ``damping``...) with vertex arguments (``source`` — a single ID,
    ``sources`` — an ID array); the registry entry declares which is
    which, so every backend resolves IDs into its own addressing
    (offsets locally, packed keys on the mesh).
    """

    name: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "params", dict(self.params))

    def cache_key(self) -> Tuple:
        """Hashable identity (epoch-memoization key in the service)."""
        return (self.name,) + tuple(sorted(
            (k, _freeze(v)) for k, v in self.params.items()))


@dataclasses.dataclass(frozen=True)
class AnalyticsResult:
    """One analytics answer plus its provenance — what the incremental
    engine chains from epoch to epoch.

    ``value`` is the normalized (backend-independent) answer, exactly what
    ``GraphStore.analytics`` returns. ``epoch`` is the capture sequence the
    answer is valid at; ``mode`` records how it was produced (``scratch``
    or ``incremental``) and ``reason`` why an advance fell back (empty
    otherwise). ``iters`` is the iteration/round count of the producing
    run. ``raw`` and ``handle`` are BACKEND-PRIVATE warm state (per-row
    value arrays + the epoch handle they align with) — an advance consumes
    them; treat them as opaque."""

    value: Any
    epoch: int
    mode: str = "scratch"
    iters: int = 0
    reason: str = ""
    raw: Any = dataclasses.field(default=None, repr=False)
    handle: Any = dataclasses.field(default=None, repr=False)


@dataclasses.dataclass(frozen=True)
class ApplyResult:
    """Outcome of one ``OpBatch``: ops admitted to the engine vs ops the
    engine refused at capacity (never UB — the paper's overflow
    discipline)."""

    applied: int
    dropped: int
