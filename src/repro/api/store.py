"""``GraphStore`` — one typed front door over every storage backend.

A store consumes the typed op IR (``OpBatch`` / ``ReadOp`` /
``AnalyticsOp``) and hides how state is laid out: ``LocalStore`` wraps the
eager single-shard ``RadixGraph``; ``ShardedStore`` wraps the
``dist.graph_engine`` factories (mesh and budgets captured at
construction, ``make_*`` closures built lazily and jit-cached per spec).
Both answer reads and analytics in the SAME backend-independent form, so
benchmarks, examples, the dryrun harness and the query service drive
either through one code path — and a new backend (multi-host epoch
handshake, another storage design, a CPU fallback) is a
``register_backend`` call, not a rewrite.

Epochs: ``capture()`` returns an O(1) immutable handle to the current
functional state (the paper's MVCC versioned arrays); every read/analytics
call accepts ``at=handle`` to answer against that version instead of the
live state. Sealing an epoch in the serving layer is just ``capture()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import edgepool as ep
from repro.core import epoch_delta as ed
from repro.core import radixgraph as rg
from repro.core import vertex_table as vt_mod
from repro.core.keys import pack_keys, unpack_keys
from repro.core.radixgraph import RadixGraph, interleave_undirected
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import optimize_sort
from repro.core.status import Reason
from repro.dist import graph_engine as ge

from .ir import (AnalyticsOp, AnalyticsResult, ApplyResult, OpBatch,
                 ReadOp, UnsupportedOpError)
from .registry import AnalyticsSpec, analytics_spec

__all__ = ["GraphStore", "Epoch", "LocalStore", "ShardedStore",
           "make_store", "register_backend", "available_backends"]


@dataclasses.dataclass(frozen=True)
class Epoch:
    """Immutable capture of a store's state. O(1): functional states are
    pytree references, so holding an Epoch IS retaining the MVCC version —
    drop the handle to free it. ``cache`` rides the handle (derived
    artifacts like the CSR snapshot), so freeing the handle frees them
    too — stores never pin a dropped epoch."""

    state: Any
    seq: int
    cache: dict = dataclasses.field(default_factory=dict, compare=False,
                                    repr=False)


@runtime_checkable
class GraphStore(Protocol):
    """The protocol every backend implements (structural — no base class
    to inherit; ``register_backend`` is the only ceremony)."""

    backend: str
    n_shards: int

    def apply(self, batch: OpBatch) -> ApplyResult: ...
    def read(self, op: ReadOp, at: Optional[Epoch] = None) -> Any: ...
    def analytics(self, op: AnalyticsOp,
                  at: Optional[Epoch] = None) -> Any: ...
    def capture(self) -> Epoch: ...
    def clock(self, at: Optional[Epoch] = None) -> int: ...


# jitted single-shard read programs shared by every LocalStore (static
# specs hash per-config, so distinct stores share compile caches the same
# way RadixGraph's module-level wrappers do)
_lookup = jax.jit(rg.step_lookup, static_argnums=(0, 1))
_degree = jax.jit(rg.step_degree_counts, static_argnums=(0, 1))
_neighbors = jax.jit(rg.step_neighbors, static_argnums=(0, 1, 4))
_snapshot = jax.jit(rg.step_snapshot, static_argnums=(0, 1, 2))


def _values_item(d: dict) -> dict:
    return {int(k): (v.item() if hasattr(v, "item") else v)
            for k, v in d.items()}


def _stale_gen(prev_handle: Optional[Epoch], at: Optional[Epoch],
               gen: int) -> bool:
    """True when either epoch handle predates the store's last
    ``restore()`` — ``capture`` stamps handles with the restore
    generation, so a warm chain can never silently span a restore (the
    restored lineage may reuse seqs, defrag counters and row offsets)."""
    return any(ep is not None and ep.cache.get("gen", 0) != gen
               for ep in (prev_handle, at))


class LocalStore:
    """Single-shard backend: the eager ``RadixGraph`` behind the IR.

    Constructor kwargs are ``RadixGraph``'s (plus ``m_cap`` — the CSR pad
    of snapshots/analytics; analytics cost scales with it, so benchmarks
    pass a tight bound). The wrapped graph stays reachable as ``.graph``
    for backend-specific extras (MVCC version labels, defrag, memory
    accounting)."""

    backend = "local"
    supported_ops = frozenset(("edges", "add_vertices", "delete_vertices"))

    def __init__(self, m_cap: Optional[int] = None,
                 max_delta_frac: float = 0.1, **graph_kwargs):
        self.graph = RadixGraph(**graph_kwargs)
        self.n_shards = 1
        self.m_cap = m_cap or self.graph.pool_spec.capacity_entries
        self.max_delta_frac = max_delta_frac
        self._seq = 0
        self._restore_gen = 0   # bumped by every restore(): epoch handles
        #                         captured before it are no longer delta-safe
        self.stats = dict(ops_applied=0, ops_dropped=0, defrags=0,
                          defrag_ms=0.0, defrag_host_ms=0.0,
                          defrag_sync_ms=0.0, tiles_scanned=0,
                          flushes=0, super_batches=0,
                          host_stage_ms=0.0, device_sync_ms=0.0)

    # ---- mutation ----
    def apply(self, batch: OpBatch) -> ApplyResult:
        if len(batch) == 0:
            return ApplyResult(0, 0)
        self._seq += 1
        g = self.graph
        if batch.kind == "edges":
            d0 = g.dropped_ops
            g.apply_ops(batch.src, batch.dst, batch.weight)
            res = ApplyResult(len(batch), g.dropped_ops - d0)
        else:
            o0 = int(g.state.vt.overflow)
            if batch.kind == "add_vertices":
                g.add_vertices(batch.ids)
            else:
                g.delete_vertices(batch.ids)
            res = ApplyResult(len(batch), int(g.state.vt.overflow) - o0)
        self.stats["ops_applied"] += res.applied
        self.stats["ops_dropped"] += res.dropped
        # maintenance counters ride every write result: the write path's
        # spike/scan accounting is a recorded artifact, not a debug log
        self.stats["defrags"] = g.num_defrags
        self.stats["defrag_ms"] = round(g.defrag_ms, 3)
        self.stats["defrag_host_ms"] = round(g.defrag_host_ms, 3)
        self.stats["defrag_sync_ms"] = round(g.defrag_sync_ms, 3)
        self.stats["tiles_scanned"] = g.tiles_scanned
        self.stats["flushes"] = g.pipe_flushes
        self.stats["super_batches"] = g.pipe_super_batches
        self.stats["host_stage_ms"] = round(g.pipe_stage_ms, 3)
        self.stats["device_sync_ms"] = round(g.pipe_sync_ms, 3)
        return res

    # ---- epochs ----
    def capture(self) -> Epoch:
        # exempt the captured state from steady-state buffer donation
        self.graph.pin_live_state()
        return Epoch(self.graph.state, self._seq,
                     cache={"gen": self._restore_gen})

    def clock(self, at: Optional[Epoch] = None) -> int:
        state = at.state if at is not None else self.graph.state
        return int(state.pool.clock) - 1

    # ---- durability hooks (repro.storage) ----
    def durable_state(self):
        """The live functional state plus the HOST counters a restored
        process needs for deterministic resume (capture seq, drop
        accounting, the defrag watermark the spike attribution uses)."""
        return self.graph.state, dict(
            seq=self._seq, dropped_ops=self.graph.dropped_ops,
            seen_defrags=self.graph._seen_defrags,
            ops_applied=self.stats["ops_applied"],
            ops_dropped=self.stats["ops_dropped"])

    def load_durable_state(self, state, meta: dict):
        """Install a checkpointed state as the live image. Epoch handles
        captured BEFORE this call are lineage-divergent: ``capture`` tags
        handles with a restore generation and ``analytics_advance``
        refuses cross-generation windows (``Reason.RESTORE_BOUNDARY``)."""
        g = self.graph
        g.state = jax.tree.map(jnp.asarray, state)
        g._invalidate()
        g.pin_live_state()      # fresh host arrays must not be donated
        g.dropped_ops = int(meta.get("dropped_ops", 0))
        g._seen_defrags = int(meta.get(
            "seen_defrags", np.asarray(g.state.pool.defrags)))
        self._seq = int(meta.get("seq", 0))
        self.stats["ops_applied"] = int(meta.get("ops_applied", 0))
        self.stats["ops_dropped"] = int(meta.get("ops_dropped", 0))
        self._restore_gen += 1

    def checkpoint(self, directory, **kw):
        """Write an epoch-consistent checkpoint of the live state (full or
        incremental — see ``repro.storage.checkpoint``)."""
        from repro.storage.checkpoint import save_graph_checkpoint
        return save_graph_checkpoint(directory, self, **kw)

    def restore(self, directory, ckpt_id: Optional[int] = None):
        """Restore the live state from the latest (or given) valid
        checkpoint chain under ``directory``."""
        from repro.storage.checkpoint import restore_graph_checkpoint
        return restore_graph_checkpoint(directory, self, ckpt_id)

    def _state(self, at: Optional[Epoch]):
        return at.state if at is not None else self.graph.state

    # ---- reads ----
    def _per_key(self, state, ids, fn):
        out = []
        for keys, _ in self.graph._key_batches(ids):
            out.append(np.asarray(fn(state, keys)))
        n = len(np.asarray(ids))
        return (np.concatenate(out)[:n] if out
                else np.zeros((0,), np.int32))

    def _snap(self, at: Optional[Epoch]):
        if at is None:
            return self.graph.snapshot(m_cap=self.m_cap)    # epoch-cached
        # epoch-pinned reads share one snapshot per handle; it rides the
        # handle's cache, so dropping the Epoch frees it with the state
        snap = at.cache.get("snap")
        if snap is None:
            snap = at.cache["snap"] = _snapshot(
                self.graph.sort_spec, self.graph.pool_spec, self.m_cap,
                at.state)
        return snap

    def read(self, op: ReadOp, at: Optional[Epoch] = None):
        g = self.graph
        state = self._state(at)
        if op.kind == "lookup":
            off = self._per_key(state, op.ids, lambda s, k: _lookup(
                g.sort_spec, g.pool_spec, s, k))
            return off >= 0
        if op.kind == "degree":
            return self._per_key(state, op.ids, lambda s, k: _degree(
                g.sort_spec, g.pool_spec, s, k))
        if op.kind == "neighbors":
            width = op.width or g.pool_spec.dmax
            ds, ws, cs = [], [], []
            for keys, _ in g._key_batches(op.ids):
                bd, bw, _, bcnt = _neighbors(g.sort_spec, g.pool_spec,
                                             state, keys, width, None)
                ds.append(np.asarray(bd))
                ws.append(np.asarray(bw))
                cs.append(np.asarray(bcnt))
            n = len(np.asarray(op.ids))
            d = np.concatenate(ds)[:n]
            w = np.concatenate(ws)[:n]
            cnt = np.concatenate(cs)[:n]
            ids_np = np.asarray(state.vt.ids)
            oc = np.clip(d, 0, ids_np.shape[0] - 1)
            gids = unpack_keys(ids_np[oc])
            return [(gids[i, :cnt[i]], w[i, :cnt[i]]) for i in range(n)]
        if op.kind == "num_vertices":
            if at is None:
                return self.graph.num_vertices
            return int(vt_mod.num_active(at.state.vt))
        if op.kind == "num_edges":
            if at is None:
                return self.graph.num_edges     # O(1) live counter
            return int(self._snap(at).m)
        if op.kind == "snapshot":
            return self._snap(at)
        raise ValueError(op.kind)

    # ---- analytics ----
    def _resolve_dyn(self, spec: AnalyticsSpec, state, params: dict):
        """Pop dyn params and resolve IDs -> row offsets. Returns
        ``(dyn, dyn_rows, absent_source)``; ``dyn_rows`` carries the host
        ints the advance phases take."""
        g = self.graph
        look = lambda s, k: _lookup(g.sort_spec, g.pool_spec, s, k)
        dyn, dyn_rows, absent_source = [], [], False
        for pname, kind in spec.dyn:
            v = params.pop(pname)
            if kind == "id":
                off = self._per_key(state, np.asarray([v], np.uint64),
                                    look)[0]
                if off < 0:
                    absent_source = True
                dyn_rows.append(max(int(off), 0))
                dyn.append(jnp.int32(max(int(off), 0)))
            else:
                ids = np.asarray(v, np.uint64)
                off = self._per_key(state, ids, look)
                if spec.result == "per_query":
                    dyn.append((jnp.asarray(np.clip(off, 0, None),
                                            jnp.int32), off))
                else:
                    # per-vertex source sets (BC): absent sources
                    # contribute nothing — drop them, like the mesh loop
                    dyn.append(jnp.asarray(off[off >= 0], jnp.int32))
        return dyn, dyn_rows, absent_source

    def _per_vertex_value(self, raw: np.ndarray, snap) -> dict:
        active = np.asarray(snap.active)
        vids = unpack_keys(np.asarray(snap.ids))
        # .tolist() yields Python scalars in one C pass — no per-vertex
        # .item() loop on the read path
        return dict(zip(vids[active].tolist(), raw[active].tolist()))

    def analytics(self, op: AnalyticsOp, at: Optional[Epoch] = None):
        return self.analytics_result(op, at).value

    def analytics_result(self, op: AnalyticsOp, at: Optional[Epoch] = None,
                         _reason: str = "") -> AnalyticsResult:
        """From-scratch run, answered as an ``AnalyticsResult`` whose
        ``raw`` per-row values seed a later ``analytics_advance``."""
        spec = analytics_spec(op.name)
        state = self._state(at)
        snap = self._snap(at)
        params = dict(op.params)
        dyn, _rows, absent_source = self._resolve_dyn(spec, state, params)
        n_cap = snap.indptr.shape[0] - 1
        iters = 0
        if absent_source:
            vals = np.full((n_cap,), spec.absent)
        else:
            args = [a[0] if isinstance(a, tuple) else a for a in dyn]
            vals = spec.single(snap, *args, **params)
            if isinstance(vals, tuple):      # convergence entries: (v, it)
                vals, it = vals
                iters = int(np.asarray(it))
        seq = at.seq if at is not None else self._seq
        if spec.result == "scalar":
            v = np.asarray(vals).item()
            return AnalyticsResult(v, seq, "scratch", iters, _reason, v, at)
        if spec.result == "per_query":
            out = np.asarray(vals).copy()
            for a in dyn:
                if isinstance(a, tuple):
                    out[np.asarray(a[1]) < 0] = 0   # absent queries -> 0
            return AnalyticsResult(out, seq, "scratch", iters, _reason,
                                   None, at)
        if spec.canonical_single is not None:
            vals = spec.canonical_single(vals, snap)
        raw = np.asarray(vals)
        return AnalyticsResult(self._per_vertex_value(raw, snap), seq,
                               "scratch", iters, _reason, raw, at)

    def _csr(self, at: Epoch) -> ed.HostCsr:
        h = at.cache.get("hcsr")
        if h is None:
            h = at.cache["hcsr"] = ed.host_csr(self._snap(at))
        return h

    def _delta(self, prev: Epoch, cur: Epoch):
        key = ("delta", prev.seq)
        hit = cur.cache.get(key)
        if hit is None:     # shared across every analytic chained E->E'
            hit = cur.cache[key] = ed.extract_delta(
                prev.state, cur.state, self._csr(prev), self._csr(cur))
        return hit

    def analytics_advance(self, op: AnalyticsOp, prev: AnalyticsResult,
                          at: Optional[Epoch]) -> AnalyticsResult:
        """Advance ``prev`` to epoch ``at`` over the delta, falling back
        to ``analytics_result`` (with the reason recorded) whenever the
        window or the algorithm refuses — callers always get the exact
        answer, ``mode`` just says how it was produced."""
        spec = analytics_spec(op.name)
        if at is None or prev is None:
            return self.analytics_result(op, at, _reason=Reason.NO_WARM)
        if _stale_gen(prev.handle, at, self._restore_gen):
            # a restore() replaced the lineage: equal seq / defrag
            # counters no longer imply equal states or row identity
            return self.analytics_result(op, at,
                                         _reason=Reason.RESTORE_BOUNDARY)
        if prev.epoch == at.seq:
            return prev
        if (spec.advance is None or spec.result == "per_query"
                or prev.handle is None or prev.raw is None):
            return self.analytics_result(op, at, _reason=Reason.NO_WARM)
        delta, reason = self._delta(prev.handle, at)
        if delta is None:
            return self.analytics_result(op, at, _reason=reason)
        if delta.n_changed > self.max_delta_frac * max(delta.m_cur, 1):
            return self.analytics_result(op, at,
                                         _reason=Reason.DELTA_TOO_LARGE)
        snap = self._snap(at)
        params = dict(op.params)
        _dyn, rows, absent = self._resolve_dyn(spec, at.state, params)
        if absent:
            return self.analytics_result(op, at,
                                         _reason=Reason.ABSENT_SOURCE)
        out = spec.advance(prev.raw, delta, self._csr(prev.handle),
                           self._csr(at), tuple(rows), params)
        if out is None:
            return self.analytics_result(op, at,
                                         _reason=Reason.ADVANCE_REFUSED)
        raw, iters = out
        if spec.result == "scalar":
            return AnalyticsResult(int(raw), at.seq, "incremental",
                                   int(iters), "", int(raw), at)
        raw = np.asarray(raw)
        return AnalyticsResult(self._per_vertex_value(raw, snap), at.seq,
                               "incremental", int(iters), "", raw, at)

    # ---- epoch retention (MVCC pins for warm chains) ----
    def pin_epoch(self, at: Epoch):
        """Register ``at`` in the graph's MVCC version set (label —
        derived from the capture seq — is private to the epoch chain)."""
        self.graph.retain_version(at.state, -(1 + at.seq))

    def release_epoch(self, at: Epoch):
        self.graph.release_version(-(1 + at.seq))

    @property
    def retained_epochs(self) -> int:
        return sum(1 for lab, _, _ in self.graph._versions if lab < 0)


class ShardedStore:
    """Mesh backend: vertex-space sharding over ``dist.graph_engine``.

    Mesh, specs and exchange budgets are captured at construction; every
    ``make_*`` closure is built LAZILY on first use and cached per static
    spec (`_fn`), so a store only compiles the programs its workload
    actually exercises. The write path keeps the live state vertex-SYNCED
    (incremental registration exchange, skipped entirely for batches that
    create no vertices) so any captured epoch is analytics-ready."""

    backend = "sharded"
    supported_ops = frozenset(("edges",))   # vertex CRUD: LocalStore only

    def __init__(self, n_shards: int = 1, *, n_per_shard: int = 8192,
                 expected_n: int = 4096, key_bits: int = 32,
                 pool_blocks: int = 16384, block_size: int = 16,
                 k_max: int = 128, dmax: int = 2048,
                 batch: int = 1024, query_batch: int = 256,
                 m_cap: Optional[int] = None, axis: str = "data",
                 undirected: bool = False, pack: bool = True,
                 capacity_factor: float = 1.0,
                 route_budget: Optional[int] = None,
                 frontier_budget: Optional[int] = None,
                 sync_incremental: bool = True,
                 sync_budget: Optional[int] = None,
                 sort_capacity_factor: Optional[float] = None,
                 pipeline_depth: int = 8,
                 donate_steady_state: bool = True,
                 fuse_scan: bool = False,
                 max_delta_frac: float = 0.1,
                 devices=None):
        from jax.sharding import AxisType
        assert batch % n_shards == 0 and query_batch % n_shards == 0, \
            "batch sizes must be divisible by the shard count"
        self.n_shards = n_shards
        self.n_per_shard = n_per_shard
        self.key_bits = key_bits
        self.batch = batch
        self.query_batch = query_batch
        self.axis = axis
        self.undirected = undirected
        self.pack = pack
        self.capacity_factor = capacity_factor
        self.route_budget = route_budget
        self.frontier_budget = frontier_budget
        self.sync_incremental = sync_incremental
        self.pipeline_depth = pipeline_depth
        self.donate_steady_state = donate_steady_state
        self.fuse_scan = fuse_scan
        self.mesh = jax.make_mesh(
            (n_shards,), (axis,),
            devices=(devices if devices is not None
                     else jax.devices()[:n_shards]),
            axis_types=(AxisType.Auto,))
        cfg = optimize_sort(expected_n, key_bits, 5)
        self.sspec = SortSpec.from_config(cfg, n_per_shard,
                                          sort_capacity_factor)
        self.pspec = ep.PoolSpec(n_blocks=pool_blocks,
                                 block_size=block_size,
                                 k_max=k_max, dmax=dmax)
        self.m_cap = m_cap or self.pspec.capacity_entries
        if sync_budget is None:
            # one write step creates at most 2 * batch rows globally
            sync_budget = min(n_per_shard, 2 * batch // n_shards + 64)
        self.sync_budget = sync_budget
        self._live_state = None  # materialized on first use (compile-only
        #                          consumers like dryrun never allocate it)
        self._fns: Dict[Any, Callable] = {}
        self._synced_rows = np.zeros((n_shards,), np.int32)
        self._seq = 0
        self._snap_cache = None        # (state-ref, per-shard snapshots)
        self._host_cache = None        # (state-ref, host id/row view)
        self._full_sync_cache = None   # (state-ref, synced-state) pair
        self._seen_defrags = 0
        self._pinned = None            # donation-exempt live state pytree
        self._restore_gen = 0          # see LocalStore._restore_gen
        self.max_delta_frac = max_delta_frac
        self._retained: Dict[int, Epoch] = {}   # pinned epoch chain
        self.stats = dict(ops_applied=0, ops_dropped=0,
                          sync_runs=0, sync_skips=0, defrags=0,
                          defrag_ms=0.0, defrag_host_ms=0.0,
                          defrag_sync_ms=0.0, tiles_scanned=0,
                          flushes=0, super_batches=0,
                          host_stage_ms=0.0, device_sync_ms=0.0)

    @property
    def state(self):
        """The live sharded state pytree, allocated lazily: AOT-lowering
        consumers (``state_struct``/``*_program``) never pay for it."""
        if self._live_state is None:
            self._live_state = ge.make_sharded_state(
                self.sspec, self.pspec, self.n_shards, self.n_per_shard)
            # the broadcast-built fresh state can share one device buffer
            # across zero-filled leaves — XLA refuses to donate an aliased
            # buffer twice, so the first dispatch must not donate
            self._pinned = self._live_state
        return self._live_state

    @state.setter
    def state(self, value):
        self._live_state = value

    # ---- lazily-built, spec-cached jitted programs ----
    def _fn(self, key, build) -> Callable:
        f = self._fns.get(key)
        if f is None:
            f = self._fns[key] = jax.jit(build())
        return f

    def apply_program(self, donate: bool = False,
                      depth: Optional[int] = None) -> Callable:
        """The jitted routed-apply program. ``depth=None`` is the per-batch
        (B, ...) entry; any int selects the K-batch pipelined entry taking
        stacked (K, B, ...) super-batches — ONE cached callable serves every
        K (jit retraces per distinct leading dim). ``donate=True`` donates
        the state pytree (steady-state buffers reuse the old pool image)."""
        def build():
            if depth is None:
                return ge.make_apply_edges(
                    self.sspec, self.pspec, self.mesh, self.axis,
                    pack=self.pack, capacity_factor=self.capacity_factor,
                    route_budget=self.route_budget)
            return ge.make_apply_edges_pipelined(
                self.sspec, self.pspec, self.mesh, self.axis,
                pack=self.pack, capacity_factor=self.capacity_factor,
                route_budget=self.route_budget)
        key = ("apply" if depth is None else "applyK",
               "donate" if donate else "plain")
        if key not in self._fns:
            f = build()
            self._fns[key] = jax.jit(f, donate_argnums=(0,)) if donate \
                else jax.jit(f)
        return self._fns[key]

    def analytics_program(self, name: str, **static) -> Callable:
        """The jitted mesh program of a registered algorithm (also the
        AOT-compile entry the dryrun harness lowers)."""
        spec = analytics_spec(name)
        if spec.make_dist is None:
            raise NotImplementedError(
                f"analytics op {name!r} has no mesh combine loop "
                f"registered (repro.api.registry) — run it on a "
                f"LocalStore, or register a distributed form")
        key = ("alg", name, tuple(sorted(static.items())))
        return self._fn(key, lambda: spec.make_dist(
            self.sspec, self.pspec, self.mesh, self.axis, self.m_cap,
            self.frontier_budget, **static))

    def warm_program(self, name: str, **static) -> Callable:
        """The jitted warm-advance mesh program (``make_dist_warm``):
        ``f(state, *dyn, prev_raw) -> (values, iters)``. Shares the
        ``("algw", ...)`` cache slot ``analytics_advance`` uses, and is
        the AOT entry ``dryrun_graph --mode analytics --incremental``
        lowers. Raises for algorithms with no warm form (or whose knobs
        disable it, e.g. fixed-iteration PageRank)."""
        spec = analytics_spec(name)
        if spec.make_dist_warm is None:
            raise NotImplementedError(
                f"analytics op {name!r} has no warm mesh program "
                f"registered (repro.api.registry)")
        key = ("algw", name, tuple(sorted(static.items())))
        f = self._fns.get(key)
        if f is None:
            built = spec.make_dist_warm(
                self.sspec, self.pspec, self.mesh, self.axis, self.m_cap,
                self.frontier_budget, **static)
            if built is None:
                raise NotImplementedError(
                    f"analytics op {name!r} refuses a warm program for "
                    f"{static!r} (path-dependent without a tolerance)")
            f = self._fns[key] = jax.jit(built)
        return f

    def state_struct(self):
        """Shape/dtype pytree of a fresh sharded state (AOT lowering)."""
        return jax.eval_shape(lambda: ge.make_sharded_state(
            self.sspec, self.pspec, self.n_shards, self.n_per_shard))

    # ---- mutation ----
    def _keys(self, ids) -> np.ndarray:
        return np.asarray(pack_keys(np.asarray(ids, np.uint64),
                                    self.key_bits))

    def apply(self, batch: OpBatch) -> ApplyResult:
        if batch.kind not in self.supported_ops:
            raise UnsupportedOpError(
                batch.kind, self.backend,
                "sharded vertex-only mutation batches are not routed yet: "
                "vertices materialize from edge endpoints (plus the owner "
                "registration sync); use LocalStore for vertex CRUD")
        if len(batch) == 0:
            return ApplyResult(0, 0)
        src, dst, w = batch.src, batch.dst, batch.weight
        if self.undirected:
            src, dst, w = interleave_undirected(src, dst, w)
        sk, dk = self._keys(src), self._keys(dst)
        B = self.batch
        N = len(src)
        NB = (N + B - 1) // B
        K = max(1, int(self.pipeline_depth))
        t0 = time.perf_counter()
        # stage the whole flush once, then dispatch (k, B, ...) super-batches
        # ASYNCHRONOUSLY — no np.asarray() per batch; the ragged tail ships
        # at its true depth k' < K (whole-batch padding would advance the
        # pool clock and break parity with the sequential path)
        psk = np.zeros((NB * B, 2), np.uint32)
        pdk = np.zeros((NB * B, 2), np.uint32)
        pw = np.zeros((NB * B,), np.float32)
        mask = np.zeros((NB * B,), bool)
        psk[:N], pdk[:N], pw[:N], mask[:N] = sk, dk, w, True
        drops = []
        i = 0
        while i < NB:
            k = min(K, NB - i)
            lo, hi = i * B, (i + k) * B
            if k > 1 and self.fuse_scan:
                # opt-in fused entry: k batches as ONE lax.scan program.
                # (Slower than k flat donated dispatches on XLA CPU — the
                # loop-carried pool scatters lose the in-place-update
                # optimization — but it is the single-program artifact the
                # dryrun lowers and the parity suite certifies.)
                donate = self.donate_steady_state and \
                    (self.state is not self._pinned)
                fn = self.apply_program(donate=donate, depth=k)
                self.state, d = fn(
                    self.state,
                    jnp.asarray(psk[lo:hi].reshape(k, B, 2)),
                    jnp.asarray(pdk[lo:hi].reshape(k, B, 2)),
                    jnp.asarray(pw[lo:hi].reshape(k, B)),
                    jnp.asarray(mask[lo:hi].reshape(k, B)))
                drops.append(d)             # device array — no sync here
            else:
                # default steady state: k flat donated dispatches with no
                # host sync between them (donation re-checked per dispatch;
                # after the first, the state is a fresh jit output)
                for a in range(lo, hi, B):
                    donate = self.donate_steady_state and \
                        (self.state is not self._pinned)
                    fn = self.apply_program(donate=donate)
                    self.state, d = fn(
                        self.state, jnp.asarray(psk[a:a + B]),
                        jnp.asarray(pdk[a:a + B]), jnp.asarray(pw[a:a + B]),
                        jnp.asarray(mask[a:a + B]))
                    drops.append(d)
            self.stats["super_batches"] += 1
            i += k
        self.stats["host_stage_ms"] = round(
            self.stats["host_stage_ms"] +
            (time.perf_counter() - t0) * 1000.0, 3)
        # ONE host sync per flush: the drop fetch forces the dispatched
        # chain; the defrag counter delta then attributes any rebuild
        # spike to this flush window instead of serializing every batch
        t1 = time.perf_counter()
        dropped = int(sum(int(np.asarray(d).sum()) for d in drops))
        dsum = int(np.asarray(self.state.pool.defrags).sum())
        if dsum != self._seen_defrags:            # some shard rebuilt
            now = time.perf_counter()
            self.stats["defrag_ms"] = round(
                self.stats["defrag_ms"] + (now - t0) * 1000.0, 3)
            # split: staged/dispatched up to t1, device-blocked after
            self.stats["defrag_host_ms"] = round(
                self.stats["defrag_host_ms"] + (t1 - t0) * 1000.0, 3)
            self.stats["defrag_sync_ms"] = round(
                self.stats["defrag_sync_ms"] + (now - t1) * 1000.0, 3)
            self._seen_defrags = dsum
        self.stats["device_sync_ms"] = round(
            self.stats["device_sync_ms"] +
            (time.perf_counter() - t1) * 1000.0, 3)
        self.stats["flushes"] += 1
        self._seq += 1
        self._snap_cache = self._host_cache = None
        # raw submitted ops (undirected doubling is an internal detail),
        # so accounting matches ApplyResult and the local backend
        self.stats["ops_applied"] += len(batch)
        self.stats["ops_dropped"] += dropped
        self.stats["defrags"] = self._seen_defrags
        self.stats["tiles_scanned"] = int(
            np.asarray(self.state.pool.tiles_scanned).sum())
        if self.sync_incremental:
            self._maybe_sync_live()
        return ApplyResult(len(batch), dropped)

    def _maybe_sync_live(self):
        """Eager incremental vertex sync after a write batch: only rows
        created since the last sync are registered at their owner shards
        (compacted exchange w/ dense fallback); a batch creating no
        vertices skips the collective entirely."""
        rows = np.asarray(self.state.vt.num_rows)
        if np.array_equal(rows, self._synced_rows):
            self.stats["sync_skips"] += 1
            return
        fn = self._fn(("sync_inc",), lambda: ge.make_sync_vertices(
            self.sspec, self.pspec, self.mesh, self.axis,
            budget=self.sync_budget, incremental=True))
        self.state = fn(self.state, jnp.asarray(self._synced_rows))
        # np.array COPIES: np.asarray on CPU is a zero-copy view of the
        # live buffer, which the next (donating) apply would invalidate
        self._synced_rows = np.array(self.state.vt.num_rows)
        self.stats["sync_runs"] += 1

    # ---- epochs ----
    def capture(self) -> Epoch:
        # the handle retains the live arrays: exempt this state from
        # steady-state buffer donation (the next apply's first dispatch
        # runs the non-donating program, later ones donate fresh outputs)
        self._pinned = self.state
        return Epoch(self.state, self._seq,
                     cache={"gen": self._restore_gen})

    def clock(self, at: Optional[Epoch] = None) -> int:
        state = at.state if at is not None else self.state
        return int(np.asarray(state.pool.clock)[0]) - 1

    # ---- durability hooks (repro.storage) ----
    def durable_state(self):
        """Shard-stacked live state plus the host counters a restored
        process resumes ingest with (capture seq, incremental-sync
        watermark, defrag watermark)."""
        return self.state, dict(
            seq=self._seq, seen_defrags=self._seen_defrags,
            synced_rows=np.asarray(self._synced_rows).tolist(),
            ops_applied=self.stats["ops_applied"],
            ops_dropped=self.stats["ops_dropped"])

    def load_durable_state(self, state, meta: dict):
        """Install a checkpointed state as the live sharded image — every
        leaf is re-placed with the live template's sharding, so a restore
        works on a fresh store of the same spec in a new process."""
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        # the leading dim of every leaf is the shard dim: place it over
        # the mesh axis explicitly (a FRESH state's broadcast-built leaves
        # sit on one device until the first dispatch, so copying the
        # template's sharding would strand the restore there)
        sharding = NamedSharding(self.mesh, P(self.axis))
        self._live_state = jax.tree.map(
            lambda a: jax.device_put(jnp.asarray(a), sharding), state)
        self._pinned = self._live_state   # aliased/fresh: never donate
        self._snap_cache = self._host_cache = self._full_sync_cache = None
        self._synced_rows = np.asarray(
            meta["synced_rows"], np.int32).copy() if "synced_rows" in meta \
            else np.array(self.state.vt.num_rows)
        self._seq = int(meta.get("seq", 0))
        self._seen_defrags = int(meta.get("seen_defrags", np.asarray(
            self.state.pool.defrags).sum()))
        self.stats["ops_applied"] = int(meta.get("ops_applied", 0))
        self.stats["ops_dropped"] = int(meta.get("ops_dropped", 0))
        self.stats["defrags"] = self._seen_defrags
        self._restore_gen += 1

    def checkpoint(self, directory, **kw):
        """Epoch-consistent checkpoint of the live sharded state."""
        from repro.storage.checkpoint import save_graph_checkpoint
        return save_graph_checkpoint(directory, self, **kw)

    def restore(self, directory, ckpt_id: Optional[int] = None):
        from repro.storage.checkpoint import restore_graph_checkpoint
        return restore_graph_checkpoint(directory, self, ckpt_id)

    def _state(self, at: Optional[Epoch]):
        return at.state if at is not None else self.state

    def _synced(self, state):
        """A vertex-synced view of ``state`` (identity when the write path
        keeps the live state registered as it goes)."""
        if self.sync_incremental:
            return state
        if self._full_sync_cache is not None and \
                self._full_sync_cache[0] is state:
            return self._full_sync_cache[1]
        fn = self._fn(("sync",), lambda: ge.make_sync_vertices(
            self.sspec, self.pspec, self.mesh, self.axis))
        synced = fn(state)
        self.stats["sync_runs"] += 1
        self._full_sync_cache = (state, synced)
        return synced

    # ---- reads ----
    def _snapshots(self, state):
        if self._snap_cache is not None and self._snap_cache[0] is state:
            return self._snap_cache[1]
        fn = self._fn(("snapshot",), lambda: ge.make_snapshot(
            self.sspec, self.pspec, self.mesh, self.axis, self.m_cap))
        snaps = fn(state)
        self._snap_cache = (state, snaps)
        return snaps

    def _host_view(self, state):
        """Host-side id/row maps of a (synced) state for lookup/neighbors:
        one device pull per state identity, then O(1) per query."""
        if self._host_cache is not None and self._host_cache[0] is state:
            return self._host_cache[1]
        ids = np.asarray(state.vt.ids)
        live = np.asarray(state.vt.del_time) == 0
        vid = unpack_keys(ids)
        owner = np.asarray(ge.shard_of_keys(
            jnp.asarray(ids.reshape(-1, 2)), self.n_shards)).reshape(
                ids.shape[:2])
        row_of = []
        present = set()
        for s in range(self.n_shards):
            rows = np.nonzero(live[s])[0]
            row_of.append(dict(zip(vid[s][rows].tolist(), rows.tolist())))
            present.update(row_of[-1])
        view = dict(vid=vid, live=live, owner=owner, row_of=row_of,
                    present=present)
        self._host_cache = (state, view)
        return view

    def read(self, op: ReadOp, at: Optional[Epoch] = None):
        state = self._state(at)
        if op.kind == "degree":
            fn = self._fn(("degree",), lambda: ge.make_khop_counts(
                self.sspec, self.pspec, self.mesh, self.axis))
            Q = self.query_batch
            keys = self._keys(op.ids)
            out = np.zeros((len(op.ids),), np.int32)
            for lo in range(0, len(op.ids), Q):
                chunk = keys[lo:lo + Q]
                buf = np.zeros((Q, 2), np.uint32)
                buf[:len(chunk)] = chunk
                cnt = np.asarray(fn(state, jnp.asarray(buf)))
                out[lo:lo + len(chunk)] = cnt[:len(chunk)]
            return out
        if op.kind == "lookup":
            present = self._host_view(self._synced(state))["present"]
            return np.array([int(x) in present for x in op.ids], bool)
        if op.kind == "neighbors":
            # edges live in the SOURCE's hash-owner shard: read that
            # shard's CSR row (host-materialized per-shard snapshots)
            view = self._host_view(state)
            snaps = self._snapshots(state)
            indptr = np.asarray(snaps.indptr)
            dst = np.asarray(snaps.dst)
            wgt = np.asarray(snaps.weight)
            out = []
            for x in np.asarray(op.ids, np.uint64):
                key = self._keys(np.asarray([x], np.uint64))
                s = int(np.asarray(ge.shard_of_keys(
                    jnp.asarray(key), self.n_shards))[0])
                row = view["row_of"][s].get(int(x))
                if row is None:
                    out.append((np.zeros((0,), np.uint64),
                                np.zeros((0,), np.float32)))
                    continue
                lo, hi = int(indptr[s][row]), int(indptr[s][row + 1])
                offs = dst[s][lo:hi]
                out.append((view["vid"][s][offs], wgt[s][lo:hi]))
            return out
        if op.kind == "num_vertices":
            view = self._host_view(self._synced(state))
            mine = view["live"] & (view["owner"] ==
                                   np.arange(self.n_shards)[:, None])
            return int(np.sum(mine))
        if op.kind == "num_edges":
            return int(np.asarray(self._snapshots(state).m).sum())
        if op.kind == "snapshot":
            return self._snapshots(state)
        raise ValueError(op.kind)

    # ---- analytics ----
    def _resolve_dyn(self, spec: AnalyticsSpec, params: dict):
        """Pop dyn params and resolve IDs -> packed mesh keys. Returns
        ``(dyn, query_ids)``."""
        dyn, query_ids = [], None
        for pname, kind in spec.dyn:
            v = params.pop(pname)
            if kind == "id":
                dyn.append(jnp.asarray(
                    self._keys(np.asarray([v], np.uint64))[0]))
            elif spec.result == "per_query":
                query_ids = np.asarray(v, np.uint64)
            else:
                # replicated source sets (BC): pad to the next power of
                # two with absent-key sentinels (hash to nothing, roff<0,
                # contribute zero) so distinct set sizes reuse a bounded
                # family of compiled programs
                ids = np.asarray(v, np.uint64)
                S = max(len(ids), 1)
                Sp = 1 << (S - 1).bit_length()
                buf = np.full((Sp, 2), 0xFFFFFFFF, np.uint32)
                buf[:len(ids)] = self._keys(ids)
                dyn.append(jnp.asarray(buf))
        return dyn, query_ids

    def analytics(self, op: AnalyticsOp, at: Optional[Epoch] = None):
        return self.analytics_result(op, at).value

    def analytics_result(self, op: AnalyticsOp, at: Optional[Epoch] = None,
                         _reason: str = "") -> AnalyticsResult:
        """From-scratch mesh run as an ``AnalyticsResult``; ``raw`` keeps
        the per-shard ``(n_shards, n_cap)`` values (scalar results: the
        per-shard partials) a later ``analytics_advance`` seeds from."""
        spec = analytics_spec(op.name)
        if op.name == "wcc" and self.key_bits > 32:
            raise NotImplementedError(
                "distributed WCC labels are single uint32 words (min "
                "vertex ID): key_bits > 32 needs a two-word label loop")
        params = dict(op.params)
        dyn, query_ids = self._resolve_dyn(spec, params)
        fn = self.analytics_program(op.name, **params)
        state = self._synced(self._state(at))
        seq = at.seq if at is not None else self._seq
        if query_ids is not None:
            # query batches ride the shard partition in fixed
            # ``query_batch`` chunks (ONE compiled shape, like the degree
            # read path); sentinel-padded tails answer 0 and are sliced
            Q = self.query_batch
            q = len(query_ids)
            keys = self._keys(query_ids)
            out = np.zeros((q,), np.int32)
            for lo in range(0, q, Q):
                n_c = min(Q, q - lo)
                buf = np.full((Q, 2), 0xFFFFFFFF, np.uint32)
                buf[:n_c] = keys[lo:lo + n_c]
                vals = np.asarray(fn(state, jnp.asarray(buf), *dyn))
                out[lo:lo + n_c] = vals[:n_c]
            return AnalyticsResult(out, seq, "scratch", 0, _reason,
                                   None, at)
        vals = fn(state, *dyn)
        iters = 0
        if isinstance(vals, tuple):         # convergence entries: (v, it)
            vals, it = vals
            iters = int(np.asarray(it).max())
        raw = np.asarray(vals)
        if spec.result == "scalar":
            return AnalyticsResult(int(raw.sum()), seq, "scratch", iters,
                                   _reason, raw, at)
        value = _values_item(
            ge.collect_owner_values(state, raw, self.n_shards))
        return AnalyticsResult(value, seq, "scratch", iters, _reason,
                               raw, at)

    def _csrs(self, at: Epoch):
        """Per-shard host CSR views of an epoch, cached on the handle."""
        h = at.cache.get("hcsr")
        if h is None:
            fn = self._fn(("snapshot",), lambda: ge.make_snapshot(
                self.sspec, self.pspec, self.mesh, self.axis, self.m_cap))
            snaps = fn(at.state)
            indptr = np.asarray(snaps.indptr)
            dst = np.asarray(snaps.dst)
            w = np.asarray(snaps.weight)
            act = np.asarray(snaps.active)
            ids = np.asarray(snaps.ids)
            m = np.asarray(snaps.m)
            h = at.cache["hcsr"] = [
                ed.HostCsr(indptr=indptr[s], dst=dst[s], weight=w[s],
                           active=act[s], ids=ids[s], m=int(m[s]))
                for s in range(self.n_shards)]
        return h

    def _delta(self, prev: Epoch, cur: Epoch):
        key = ("delta", prev.seq)
        hit = cur.cache.get(key)
        if hit is None:     # shared across every analytic chained E->E'
            hit = cur.cache[key] = ed.extract_delta_sharded(
                prev.state, cur.state, self._csrs(prev), self._csrs(cur))
        return hit

    def analytics_advance(self, op: AnalyticsOp, prev: AnalyticsResult,
                          at: Optional[Epoch]) -> AnalyticsResult:
        """Advance ``prev`` to epoch ``at``: warm mesh program when the
        registry has one (``make_dist_warm``), per-shard host advance
        otherwise (degree/num_edges — shard-local by the edge-placement
        invariant); any refusal falls back to scratch with the reason."""
        spec = analytics_spec(op.name)
        if at is None or prev is None:
            return self.analytics_result(op, at, _reason=Reason.NO_WARM)
        if _stale_gen(prev.handle, at, self._restore_gen):
            return self.analytics_result(op, at,
                                         _reason=Reason.RESTORE_BOUNDARY)
        if prev.epoch == at.seq:
            return prev
        if (spec.result == "per_query" or prev.handle is None
                or prev.raw is None or not self.sync_incremental
                or (spec.make_dist_warm is None and spec.advance is None)):
            return self.analytics_result(op, at, _reason=Reason.NO_WARM)
        deltas, reason = self._delta(prev.handle, at)
        if deltas is None:
            return self.analytics_result(op, at, _reason=reason)
        flags = ed.merged_flags(deltas)
        if flags["n_changed"] > self.max_delta_frac * \
                max(flags["m_cur"], 1):
            return self.analytics_result(op, at,
                                         _reason=Reason.DELTA_TOO_LARGE)
        if spec.warm_guard is not None:
            why = spec.warm_guard(flags)
            if why:
                return self.analytics_result(op, at, _reason=why)
        params = dict(op.params)
        dyn, _q = self._resolve_dyn(spec, params)
        if spec.make_dist_warm is not None:
            key = ("algw", op.name, tuple(sorted(params.items())))
            if key not in self._fns:
                f = spec.make_dist_warm(
                    self.sspec, self.pspec, self.mesh, self.axis,
                    self.m_cap, self.frontier_budget, **params)
                if f is None:       # e.g. fixed-iteration PageRank
                    return self.analytics_result(
                        op, at, _reason=Reason.NO_WARM_PROGRAM)
                self._fns[key] = jax.jit(f)
            fn = self._fns[key]
            vals, it = fn(at.state, *dyn, jnp.asarray(prev.raw))
            iters = int(np.asarray(it).max())
            raw = np.asarray(vals)
        else:
            pcsrs, ccsrs = self._csrs(prev.handle), self._csrs(at)
            raws, iters = [], 0
            for s in range(self.n_shards):
                o = spec.advance(prev.raw[s], deltas[s], pcsrs[s],
                                 ccsrs[s], (), params)
                if o is None:
                    return self.analytics_result(
                        op, at, _reason=Reason.ADVANCE_REFUSED)
                r, its = o
                raws.append(r)
                iters = max(iters, int(its))
            raw = np.asarray(raws) if spec.result == "scalar" \
                else np.stack(raws)
        if spec.result == "scalar":
            return AnalyticsResult(int(np.asarray(raw).sum()), at.seq,
                                   "incremental", iters, "", raw, at)
        value = _values_item(
            ge.collect_owner_values(at.state, raw, self.n_shards))
        return AnalyticsResult(value, at.seq, "incremental", iters, "",
                               raw, at)

    # ---- epoch retention (warm-chain pins) ----
    def pin_epoch(self, at: Epoch):
        self._retained[at.seq] = at

    def release_epoch(self, at: Epoch):
        self._retained.pop(at.seq, None)

    @property
    def retained_epochs(self) -> int:
        return len(self._retained)


# ---- backend registry ----

_BACKENDS: Dict[str, Callable[..., GraphStore]] = {}


def register_backend(name: str, factory: Callable[..., GraphStore]):
    """Register a GraphStore backend under ``name`` (see ``make_store``)."""
    _BACKENDS[name] = factory
    return factory


def available_backends():
    return sorted(_BACKENDS)


def make_store(backend: str, **kwargs) -> GraphStore:
    """Construct a registered backend: ``make_store('local', n_max=...)``
    or ``make_store('sharded', n_shards=...)``."""
    if backend not in _BACKENDS:
        raise KeyError(f"unknown GraphStore backend {backend!r}; "
                       f"registered: {available_backends()}")
    return _BACKENDS[backend](**kwargs)


register_backend("local", LocalStore)
register_backend("sharded", ShardedStore)
