"""``repro.api`` — the unified GraphStore front door.

One typed surface over every storage backend (RapidStore-style decoupled
query/update interface; one API so storage designs can be swapped and
compared under identical workloads):

    from repro.api import (GraphStore, OpBatch, ReadOp, AnalyticsOp,
                           make_store)

    store = make_store("local", n_max=4096, expected_n=1000)   # or "sharded"
    store.apply(OpBatch.edges(src, dst, w))
    deg = store.read(ReadOp("degree", ids=ids))
    pr = store.analytics(AnalyticsOp("pagerank", {"iters": 20}))

Backends answer the same ops in the same form, so benchmarks, examples,
the dryrun harness and ``serve.GraphQueryService`` all drive through this
module; the analytics registry (``repro.api.registry``) maps algorithm
names to (shard-local phases, mesh combine loop) pairs.
"""
from .ir import (AnalyticsOp, ApplyResult, OpBatch, ReadOp,
                 UnsupportedOpError)
from .registry import (ANALYTICS, AnalyticsSpec, analytics_spec,
                       available_analytics, register_analytics)
from .store import (Epoch, GraphStore, LocalStore, ShardedStore,
                    available_backends, make_store, register_backend)

__all__ = [
    "AnalyticsOp", "ApplyResult", "OpBatch", "ReadOp", "UnsupportedOpError",
    "ANALYTICS", "AnalyticsSpec", "analytics_spec", "available_analytics",
    "register_analytics",
    "Epoch", "GraphStore", "LocalStore", "ShardedStore",
    "available_backends", "make_store", "register_backend",
]
