"""GAPBS-style analytics over a RadixGraph snapshot (paper §4.4).

All algorithms run on the CSR ``GraphSnapshot`` whose ``dst`` column holds
vertex *offsets* — the paper's edge chain: after the initial source lookup,
no vertex-index access ever happens (Fig. 6). Everything is jit-compatible
with `lax.while_loop` level iteration and segment reductions (TPU-friendly:
the hot loop is gathers + scatter-reduce over the flat edge array).

The edge-chain ablation (paper Table 6) is benchmarked by routing each hop
through IDs + SORT lookups instead — see benchmarks/table6_ablation.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INF = jnp.float32(3.4e38)


def edge_sources(indptr: jnp.ndarray, m_cap: int) -> jnp.ndarray:
    """src offset of every CSR edge slot (searchsorted over indptr)."""
    e = jnp.arange(m_cap, dtype=jnp.int32)
    return (jnp.searchsorted(indptr, e, side="right") - 1).astype(jnp.int32)


def _edge_valid(snap):
    m_cap = snap.dst.shape[0]
    e = jnp.arange(m_cap, dtype=jnp.int32)
    return e < snap.m


# --------------------------------------------------------------------------
# shard-local phases
#
# The per-level / per-iteration edge work of BFS and PageRank only ever
# touches the LOCAL CSR: these phases are shared verbatim by the single-shard
# algorithms below and by ``dist.graph_engine``, whose distributed loops run
# one local phase per shard and then exchange frontiers / inflows over the
# mesh axis (the combine phase).
# --------------------------------------------------------------------------

def csr_edges(snap):
    """Loop-invariant local edge view (src row, validity, routed dst) —
    build it ONCE outside a level/iteration loop and pass it to the phases
    below, so the O(m_cap) searchsorted is never recomputed per level."""
    n = snap.indptr.shape[0] - 1
    src = edge_sources(snap.indptr, snap.dst.shape[0])
    ok = _edge_valid(snap)
    dst = jnp.where(ok, snap.dst, n)  # out-of-range -> dropped
    return src, ok, dst


def bfs_expand(snap, frontier: jnp.ndarray, edges=None) -> jnp.ndarray:
    """One level expansion over the local CSR: bool[n] frontier -> bool[n]
    rows hit by an out-edge of a frontier row."""
    n = snap.indptr.shape[0] - 1
    src, ok, dst = edges if edges is not None else csr_edges(snap)
    live = ok & frontier[jnp.clip(src, 0, n - 1)]
    return jnp.zeros((n + 1,), bool).at[jnp.where(live, dst, n)].max(
        True)[:n]


def pagerank_contrib(snap, pr: jnp.ndarray) -> jnp.ndarray:
    """Per-row outgoing contribution pr/deg (0 for dangling rows)."""
    deg = (snap.indptr[1:] - snap.indptr[:-1]).astype(jnp.float32)
    return jnp.where(deg > 0, pr / jnp.maximum(deg, 1.0), 0.0)


def pagerank_scatter(snap, contrib: jnp.ndarray, edges=None) -> jnp.ndarray:
    """Scatter contributions along local CSR edges: float[n] -> inflow[n]."""
    n = snap.indptr.shape[0] - 1
    src, ok, dst = edges if edges is not None else csr_edges(snap)
    return jnp.zeros((n + 1,)).at[dst].add(
        jnp.where(ok, contrib[jnp.clip(src, 0, n - 1)], 0.0))[:n]


@functools.partial(jax.jit, static_argnames=("max_iters",))
def bfs(snap, source: jnp.ndarray, max_iters: int = 64):
    """Level-synchronous BFS. Returns int32 depth per offset (-1 unreachable)."""
    n = snap.indptr.shape[0] - 1
    edges = csr_edges(snap)

    depth0 = jnp.full((n,), -1, jnp.int32).at[source].set(0)
    frontier0 = jnp.zeros((n,), bool).at[source].set(True)

    def cond(c):
        depth, frontier, it = c
        return jnp.any(frontier) & (it < max_iters)

    def body(c):
        depth, frontier, it = c
        nxt = bfs_expand(snap, frontier, edges) & (depth < 0)
        depth = jnp.where(nxt, it + 1, depth)
        return depth, nxt, it + 1

    depth, _, _ = jax.lax.while_loop(cond, body, (depth0, frontier0,
                                                  jnp.int32(0)))
    return depth


@functools.partial(jax.jit, static_argnames=("max_iters",))
def sssp(snap, source: jnp.ndarray, max_iters: int = 64):
    """Bellman-Ford (non-negative weights). float32 distances, INF=unreached."""
    n = snap.indptr.shape[0] - 1
    m_cap = snap.dst.shape[0]
    src = edge_sources(snap.indptr, m_cap)
    ok = _edge_valid(snap)
    dst = jnp.where(ok, snap.dst, n)
    w = jnp.where(ok, snap.weight, 0.0)

    dist0 = jnp.full((n,), INF).at[source].set(0.0)

    def cond(c):
        dist, changed, it = c
        return changed & (it < max_iters)

    def body(c):
        dist, _, it = c
        cand = jnp.where(ok, dist[jnp.clip(src, 0, n - 1)] + w, INF)
        relax = jnp.full((n + 1,), INF).at[dst].min(cand)
        nd = jnp.minimum(dist, relax[:n])
        return nd, jnp.any(nd < dist), it + 1

    dist, _, _ = jax.lax.while_loop(cond, body, (dist0, jnp.bool_(True),
                                                 jnp.int32(0)))
    return dist


@functools.partial(jax.jit, static_argnames=("iters",))
def pagerank(snap, iters: int = 20, damping: float = 0.85):
    deg = (snap.indptr[1:] - snap.indptr[:-1]).astype(jnp.float32)
    edges = csr_edges(snap)
    active = snap.active
    n_act = jnp.maximum(jnp.sum(active.astype(jnp.float32)), 1.0)

    pr0 = jnp.where(active, 1.0 / n_act, 0.0)

    def step(pr, _):
        contrib = pagerank_contrib(snap, pr)
        dangling = jnp.sum(jnp.where(active & (deg == 0), pr, 0.0))
        inflow = pagerank_scatter(snap, contrib, edges)
        pr = jnp.where(active,
                       (1 - damping) / n_act + damping * (inflow + dangling / n_act),
                       0.0)
        return pr, None

    pr, _ = jax.lax.scan(step, pr0, None, length=iters)
    return pr


@functools.partial(jax.jit, static_argnames=("max_iters",))
def wcc(snap, max_iters: int = 64):
    """Weakly connected components by min-label propagation + pointer jumping.
    Assumes edges inserted symmetrically (paper treats graphs as undirected)."""
    n = snap.indptr.shape[0] - 1
    m_cap = snap.dst.shape[0]
    src = edge_sources(snap.indptr, m_cap)
    ok = _edge_valid(snap)
    dst = jnp.where(ok, snap.dst, n)
    label0 = jnp.where(snap.active, jnp.arange(n, dtype=jnp.int32), n)

    def cond(c):
        lab, changed, it = c
        return changed & (it < max_iters)

    def body(c):
        lab, _, it = c
        cand = jnp.where(ok, lab[jnp.clip(src, 0, n - 1)], n)
        pull = jnp.full((n + 1,), n, jnp.int32).at[dst].min(cand)
        nl = jnp.minimum(lab, pull[:n])
        # pointer jumping (hook): label <- label[label]
        nl = jnp.minimum(nl, nl[jnp.clip(nl, 0, n - 1)])
        return nl, jnp.any(nl < lab), it + 1

    lab, _, _ = jax.lax.while_loop(cond, body, (label0, jnp.bool_(True),
                                                jnp.int32(0)))
    return jnp.where(snap.active, lab, -1)


@jax.jit
def triangle_count(snap):
    """Triangle count via sorted-adjacency merge on the CSR (undirected,
    symmetric edges; each triangle counted 6x as directed wedges).

    Vectorized merge: for each edge (u, v) count |N(u) ∩ N(v)| using
    searchsorted over v's sorted adjacency — O(m·lg d) gathers, segment-sum.
    Suitable for the benchmark scale; the dominant cost is intersection, as
    the paper notes (§4.4 TC gains are limited for RadixGraph).
    """
    n = snap.indptr.shape[0] - 1
    m_cap = snap.dst.shape[0]
    src = edge_sources(snap.indptr, m_cap)
    ok = _edge_valid(snap)
    dst = jnp.where(ok, snap.dst, 0)
    srcc = jnp.clip(src, 0, n - 1)

    # For every edge e=(u,v) and every neighbor w of u (same CSR row as e),
    # test membership w in N(v) by binary search. We bound row width by
    # iterating over "wedge slots": edge e x row position handled by a
    # flat loop over m_cap via membership of each edge's dst in N(src-dst).
    # Count wedges (u->v, v->w) where w in N(u):
    # for each edge f=(v,w): for u it belongs as second hop of edges into v.
    # Simpler equivalent: sum over edges f=(v,w) of |N(v) ∩ N(w)| gives
    # 2x directed triangle closures; with full symmetry total/6.
    lo = snap.indptr[jnp.clip(dst, 0, n - 1)]
    hi = snap.indptr[jnp.clip(dst, 0, n - 1) + 1]

    # Wedge formulation: for edge e=(u,v) and each neighbor w = N(u)[r],
    # triangle iff (v,w) is an edge — tested by binary search over v's sorted
    # CSR row [lo, hi). Each triangle is counted 6x (3 pivots x 2 orders).
    # Static shapes require capping the per-row scan at DMAX_TRI.
    DMAX_TRI = 256
    row_start = snap.indptr[srcc]
    deg_u = snap.indptr[srcc + 1] - row_start

    def body(r, acc):
        e2 = row_start + r
        in_row = (r < deg_u) & ok
        w = jnp.where(in_row, snap.dst[jnp.clip(e2, 0, m_cap - 1)], -1)
        # per-edge binary search for w over the row [lo, hi) of v:
        l, h = lo, hi

        def bs(_, lh):
            l, h = lh
            mid = (l + h) // 2
            val = snap.dst[jnp.clip(mid, 0, m_cap - 1)]
            go_r = val < w
            return jnp.where(go_r, mid + 1, l), jnp.where(go_r, h, mid)

        l, h = jax.lax.fori_loop(0, 32, bs, (l, h))
        found = (l < hi) & (snap.dst[jnp.clip(l, 0, m_cap - 1)] == w) & (w >= 0)
        return acc + jnp.sum((found & in_row).astype(jnp.int32))

    total = jax.lax.fori_loop(0, DMAX_TRI, body, jnp.int32(0))
    return total // 6


@functools.partial(jax.jit, static_argnames=("max_depth",))
def bc(snap, sources: jnp.ndarray, max_depth: int = 32):
    """Brandes betweenness (unweighted, sampled sources), GAPBS-style.

    Forward: level-synchronous BFS accumulating path counts sigma; backward:
    dependency accumulation over levels. Returns centrality per offset.
    """
    n = snap.indptr.shape[0] - 1
    m_cap = snap.dst.shape[0]
    src = edge_sources(snap.indptr, m_cap)
    ok = _edge_valid(snap)
    dst = jnp.where(ok, snap.dst, n)
    srcc = jnp.clip(src, 0, n - 1)

    def one_source(s):
        depth = jnp.full((n,), -1, jnp.int32).at[s].set(0)
        sigma = jnp.zeros((n,), jnp.float32).at[s].set(1.0)

        def fwd2(i, c):
            depth, sigma = c
            on_lvl = depth[srcc] == i
            add = jnp.zeros((n + 1,)).at[dst].add(
                jnp.where(ok & on_lvl, sigma[srcc], 0.0))[:n]
            newly = (add > 0) & (depth < 0)
            depth = jnp.where(newly, i + 1, depth)
            sigma = jnp.where(depth == i + 1, sigma + add, sigma)
            return depth, sigma

        depth, sigma = jax.lax.fori_loop(0, max_depth, fwd2, (depth, sigma))

        delta = jnp.zeros((n,), jnp.float32)

        def bwd(k, delta):
            lvl = max_depth - 1 - k
            # edges u->v with depth[u]==lvl, depth[v]==lvl+1
            du = depth[srcc]
            dv = depth[jnp.clip(dst, 0, n - 1)]
            onedge = ok & (du == lvl) & (dv == lvl + 1)
            contrib = jnp.where(onedge,
                                (sigma[srcc] / jnp.maximum(
                                    sigma[jnp.clip(dst, 0, n - 1)], 1.0)) *
                                (1.0 + delta[jnp.clip(dst, 0, n - 1)]), 0.0)
            acc = jnp.zeros((n + 1,)).at[jnp.where(onedge, srcc, n)].add(
                contrib)[:n]
            return delta + acc

        delta = jax.lax.fori_loop(0, max_depth, bwd, delta)
        return delta.at[s].set(0.0)

    deltas = jax.vmap(one_source)(sources)
    return jnp.sum(deltas, axis=0)


@functools.partial(jax.jit, static_argnames=("k",))
def khop(snap, sources: jnp.ndarray, k: int = 2):
    """k-hop neighborhood sizes for a batch of source offsets (paper §4.4).
    Only the initial sources required a SORT lookup — the hops run entirely
    on offsets (edge chain)."""
    n = snap.indptr.shape[0] - 1
    m_cap = snap.dst.shape[0]
    src = edge_sources(snap.indptr, m_cap)
    ok = _edge_valid(snap)
    dst = jnp.where(ok, snap.dst, n)
    srcc = jnp.clip(src, 0, n - 1)

    def one(s):
        seen = jnp.zeros((n,), bool).at[s].set(True)
        frontier = seen

        def hop(_, c):
            seen, frontier = c
            live = ok & frontier[srcc]
            hit = jnp.zeros((n + 1,), bool).at[jnp.where(live, dst, n)].max(
                True)[:n]
            nf = hit & ~seen
            return seen | nf, nf

        seen, _ = jax.lax.fori_loop(0, k, hop, (seen, frontier))
        return jnp.sum(seen.astype(jnp.int32)) - 1

    return jax.vmap(one)(sources)
