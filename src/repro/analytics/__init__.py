from .algorithms import (INF, bfs, sssp, pagerank, wcc, triangle_count, bc,
                         khop, edge_sources, csr_edges, bfs_expand,
                         pagerank_contrib, pagerank_scatter)

__all__ = ["INF", "bfs", "sssp", "pagerank", "wcc", "triangle_count", "bc",
           "khop", "edge_sources", "csr_edges", "bfs_expand",
           "pagerank_contrib", "pagerank_scatter"]
