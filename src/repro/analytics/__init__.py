from .algorithms import (bfs, sssp, pagerank, wcc, triangle_count, bc, khop,
                         edge_sources)

__all__ = ["bfs", "sssp", "pagerank", "wcc", "triangle_count", "bc", "khop",
           "edge_sources"]
