"""Incremental (epoch-delta) analytics: advance a cached result from epoch
E to E' using only the ``EpochDelta`` between them.

Every advance is EXACT against its from-scratch counterpart — BFS / WCC /
SSSP / degree by construction (fixed points of monotone relaxations are
schedule-independent), PageRank within the convergence tolerance (the
fixed point of the damped affine map is unique, so a warm start changes
the path, not the destination). Each returns ``None`` whenever the delta
violates its monotonicity precondition (deletes for BFS/WCC, deletes or
weight increases for SSSP, push-budget blowout for PageRank); the store
then falls back to scratch, so callers never observe an approximate
answer.

Host-side advances work on ``HostCsr`` views (numpy), not device
programs: the whole point is that O(delta)-local work beats a full-graph
dispatch. The device-side warm-start entry (``pagerank_converge``) backs
the tolerance-gated scratch path and the sharded warm programs in
``dist.graph_engine``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.epoch_delta import EpochDelta, HostCsr

__all__ = ["pagerank_converge", "advance_degree", "advance_num_edges",
           "advance_wcc", "advance_bfs", "advance_sssp", "advance_pagerank",
           "BFS_INF"]

BFS_INF = np.int64(1) << 30


# --------------------------------------------------------------------------
# device-side: tolerance-converged PageRank (scratch-with-tol + warm seed)
# --------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("iters", "damping", "tol", "uniform0"))
def pagerank_converge(snap, pr0, iters: int = 200, damping: float = 0.85,
                      tol: float = 1e-7, uniform0: bool = False):
    """PageRank to convergence: iterate until ``max|Δpr| < tol`` (or the
    ``iters`` cap). ``uniform0=True`` ignores ``pr0`` and starts uniform
    (the scratch entry); otherwise ``pr0`` seeds the loop (warm start).
    Returns ``(pr, iterations_run)`` — the fixed point is unique, so both
    starts land within ``tol * damping / (1 - damping)`` of it."""
    from repro.analytics import algorithms as alg
    deg = (snap.indptr[1:] - snap.indptr[:-1]).astype(jnp.float32)
    edges = alg.csr_edges(snap)
    active = snap.active
    n_act = jnp.maximum(jnp.sum(active.astype(jnp.float32)), 1.0)
    pr_init = jnp.where(active, 1.0 / n_act, 0.0) if uniform0 \
        else jnp.where(active, pr0, 0.0)

    def step(pr):
        contrib = alg.pagerank_contrib(snap, pr)
        dangling = jnp.sum(jnp.where(active & (deg == 0), pr, 0.0))
        inflow = alg.pagerank_scatter(snap, contrib, edges)
        return jnp.where(active, (1 - damping) / n_act +
                         damping * (inflow + dangling / n_act), 0.0)

    def cond(c):
        _, ch, it = c
        return (ch >= tol) & (it < iters)

    def body(c):
        pr, _, it = c
        nxt = step(pr)
        return nxt, jnp.max(jnp.abs(nxt - pr)), it + 1

    pr, _, it = jax.lax.while_loop(
        cond, body, (pr_init, jnp.float32(jnp.inf), jnp.int32(0)))
    return pr, it


# --------------------------------------------------------------------------
# host-side advances
# --------------------------------------------------------------------------

def _rows_edges(indptr: np.ndarray, rows: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
    """CSR edge indices of ``rows`` plus the per-edge source row
    (vectorized ragged gather — no per-row Python loop)."""
    counts = (indptr[rows + 1] - indptr[rows]).astype(np.int64)
    tot = int(counts.sum())
    if tot == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    base = np.repeat(indptr[rows].astype(np.int64), counts)
    off = np.arange(tot, dtype=np.int64) - \
        np.repeat(np.cumsum(counts) - counts, counts)
    return base + off, np.repeat(rows.astype(np.int64), counts)


def advance_degree(prev_vals: np.ndarray, delta: EpochDelta,
                   csr_prev: HostCsr, csr_cur: HostCsr
                   ) -> Optional[Tuple[np.ndarray, int]]:
    """Patch live out-degrees at touched rows only."""
    vals = np.asarray(prev_vals, np.int32).copy()
    rows = delta.touched_rows
    vals[rows] = csr_cur.deg[rows]
    return vals, 0


def advance_num_edges(prev_val: int, delta: EpochDelta
                      ) -> Optional[Tuple[int, int]]:
    ins = int(delta.inserts.sum())
    dels = int(delta.deletes.sum())
    return int(prev_val) + ins - dels, 0


def advance_wcc(prev_vals: np.ndarray, delta: EpochDelta,
                csr_cur: HostCsr) -> Optional[Tuple[np.ndarray, int]]:
    """Hook-union over canonical (min-member-ID) component labels for an
    insert-only delta. Every previous label IS the min vertex ID of its
    members, so min-rooted union-find over labels yields exactly the new
    canonical labeling. Deletes can split components -> fallback."""
    if delta.has_deletes:
        return None
    labels = np.asarray(prev_vals, np.uint64).copy()
    vid = csr_cur.vid64()
    labels[delta.new_rows] = vid[delta.new_rows]

    parent: dict = {}

    def find(x: int) -> int:
        root = x
        while parent.get(root, root) != root:
            root = parent[root]
        while parent.get(x, x) != root:
            parent[x], x = root, parent[x]
        return root

    unions = 0
    ins = delta.inserts
    for u, v in zip(delta.e_src[ins].tolist(), delta.e_dst[ins].tolist()):
        ra, rb = find(int(labels[u])), find(int(labels[v]))
        if ra != rb:
            if rb < ra:
                ra, rb = rb, ra
            parent[rb] = ra
            unions += 1

    out = labels.copy()
    live = np.nonzero(csr_cur.active)[0]
    lv = labels[live]
    uniq = np.unique(lv)
    roots = np.array([find(int(x)) for x in uniq.tolist()], np.uint64)
    out[live] = roots[np.searchsorted(uniq, lv)]
    return out, unions


def advance_bfs(prev_vals: np.ndarray, delta: EpochDelta, csr_cur: HostCsr,
                source_row: int, max_iters: int
                ) -> Optional[Tuple[np.ndarray, int]]:
    """Re-relax depths from the affected frontier. Insert-only-safe
    (weight changes don't touch connectivity): depths only decrease, and
    the relaxation's fixed point is the true distance — identical to the
    level-synchronous scratch run, truncation mask included."""
    if delta.has_deletes:
        return None
    n = csr_cur.n_cap
    prev = np.asarray(prev_vals, np.int64)
    d = np.where(prev >= 0, prev, BFS_INF)
    frontier = np.zeros(n, bool)
    ins = delta.inserts
    frontier[delta.e_src[ins]] = True
    if d[source_row] > 0:
        d[source_row] = 0
        frontier[source_row] = True
    rounds = 0
    indptr, dst = csr_cur.indptr, csr_cur.dst
    while frontier.any():
        if rounds > n + 2:
            return None                     # never expected: paranoia cap
        act = np.nonzero(frontier)[0]
        eidx, rep = _rows_edges(indptr, act)
        relax = np.full(n, BFS_INF, np.int64)
        if eidx.size:
            np.minimum.at(relax, dst[eidx], d[rep] + 1)
        improved = relax < d
        d = np.minimum(d, relax)
        frontier = improved
        rounds += 1
    vals = np.where(d <= max_iters, d, -1).astype(np.int32)
    return vals, rounds


def advance_sssp(prev_vals: np.ndarray, delta: EpochDelta, csr_cur: HostCsr,
                 source_row: int, max_iters: int
                 ) -> Optional[Tuple[np.ndarray, int]]:
    """Label-correcting re-relaxation in float32 (the same left-to-right
    path sums the device Bellman-Ford computes, so the fixed point is
    bit-identical). Monotone-safe only when distances can't grow:
    deletes or weight increases -> fallback. Assumes the previous scratch
    run converged within its iteration cap (holds at every benchmarked
    scale)."""
    if delta.has_deletes or delta.has_weight_increase:
        return None
    n = csr_cur.n_cap
    d = np.asarray(prev_vals, np.float32).copy()
    frontier = np.zeros(n, bool)
    changed = delta.inserts | delta.updates
    frontier[delta.e_src[changed]] = True
    if d[source_row] > 0:
        d[source_row] = np.float32(0.0)
        frontier[source_row] = True
    rounds = 0
    indptr, dst, w = csr_cur.indptr, csr_cur.dst, csr_cur.weight
    while frontier.any():
        if rounds > 16 * max_iters + 64:
            return None                     # float pathologies: fall back
        act = np.nonzero(frontier)[0]
        eidx, rep = _rows_edges(indptr, act)
        relax = np.full(n, np.float32(np.inf), np.float32)
        if eidx.size:
            cand = (d[rep].astype(np.float32) +
                    w[eidx].astype(np.float32)).astype(np.float32)
            np.minimum.at(relax, dst[eidx], cand)
        improved = relax < d
        d = np.minimum(d, relax).astype(np.float32)
        frontier = improved
        rounds += 1
    return d, rounds


def advance_pagerank(prev_vals: np.ndarray, csr_cur: HostCsr,
                     damping: float, tol: float,
                     max_rounds: int = 400,
                     edge_work_factor: int = 32
                     ) -> Optional[Tuple[np.ndarray, int]]:
    """Localized residual push (Gauss-Southwell, vectorized rounds).

    Invariant: ``pr* = x + (I - d·Pᵀ)⁻¹ · res`` — pushing a residual
    entry moves it into ``x`` and forwards ``d``·entry along out-edges
    (uniformly for dangling rows), so when ``‖res‖₁ ≤ (1-d)·tol/2`` the
    answer is provably within ``tol/2`` of the unique fixed point —
    tighter than the device loop's own stopping error. The initial
    residual is computed EXACTLY on the new graph, so any delta
    (including structural ones) is handled; locality is a performance
    property, not a correctness assumption. Returns ``None`` when the
    push budget (``edge_work_factor``·m edge traversals) or round cap is
    exhausted — the delta was too global to win."""
    indptr, dst, active = csr_cur.indptr, csr_cur.dst, csr_cur.active
    n = csr_cur.n_cap
    m = csr_cur.m
    deg = csr_cur.deg.astype(np.int64)
    act_rows = np.nonzero(active)[0]
    n_act = max(int(active.sum()), 1)
    d = float(damping)

    x = np.where(active, np.asarray(prev_vals, np.float64), 0.0)
    # exact residual r = F(x) - x over the current graph
    e_src_all = np.repeat(np.arange(n, dtype=np.int64), deg)
    contrib = np.where(deg > 0, x / np.maximum(deg, 1), 0.0)
    inflow = np.bincount(dst[:m].astype(np.int64),
                         weights=contrib[e_src_all], minlength=n)[:n]
    dangling = float(x[active & (deg == 0)].sum())
    fx = np.where(active, (1.0 - d) / n_act +
                  d * (inflow + dangling / n_act), 0.0)
    res = fx - x

    target = max(float(tol), 1e-9) * (1.0 - d) * 0.5
    theta = target / (2.0 * n_act)
    budget = edge_work_factor * (m + 1024)
    work = 0
    rounds = 0
    while float(np.abs(res[act_rows]).sum()) > target:
        push = active & (np.abs(res) > theta)
        if not push.any():
            break           # sub-threshold mass already satisfies target
        if rounds >= max_rounds:
            return None
        rows = np.nonzero(push)[0]
        rv = res[rows].copy()
        x[rows] += rv
        res[rows] = 0.0
        counts = deg[rows]
        work += int(counts.sum())
        if work > budget:
            return None
        eidx, _ = _rows_edges(indptr, rows)
        if eidx.size:
            per_edge = d * np.repeat(rv / np.maximum(counts, 1),
                                     counts)
            res += np.bincount(dst[eidx].astype(np.int64),
                               weights=per_edge, minlength=n)[:n]
        dmass = d * float(rv[counts == 0].sum())
        if dmass != 0.0:
            res[act_rows] += dmass / n_act
        rounds += 1
    return np.where(active, x, 0.0).astype(np.float32), rounds
