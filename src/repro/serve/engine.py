"""Batched serving engine: continuous batching over fixed decode slots.

Requests are admitted through the RadixKV manager (block accounting with the
snapshot-log lifecycle); prefill fills a slot's cache, then all active slots
decode in lockstep (one jitted decode per step). Finished slots are recycled
at RadixKV defrag epochs. Greedy sampling (argmax) by default.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .radix_kv import RadixKVManager


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    out: Optional[List[int]] = None
    slot: int = -1
    sid: int = -1
    pos: int = 0
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, slots: int = 8, smax: int = 256,
                 kv_blocks: int = 4096, block_tokens: int = 16,
                 eos_id: Optional[int] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.slots = slots
        self.smax = smax
        self.eos_id = eos_id
        self.kv = RadixKVManager(total_blocks=kv_blocks,
                                 block_tokens=block_tokens)
        _merge_slot.slots = slots
        self.cache = model.init_cache(slots, smax)
        self.free_slots = list(range(slots))
        self.active: Dict[int, Request] = {}
        self._decode = jax.jit(model.decode, donate_argnums=(2,))
        self._prefill_cache = {}

    # -- single-slot prefill: run the prompt through prefill at batch=slots
    # (only the target row is meaningful; the others are masked padding) --
    def _prefill_into_slot(self, req: Request):
        S = len(req.prompt)
        toks = np.zeros((self.slots, S), np.int32)
        toks[req.slot] = req.prompt
        key = S
        if key not in self._prefill_cache:
            # NOT donated: the pre-prefill cache is still read by the merge
            self._prefill_cache[key] = jax.jit(self.model.prefill)
        logits, cache = self._prefill_cache[key](
            self.params, {"tokens": jnp.asarray(toks)}, self.cache)
        # merge: only req.slot's cache rows changed meaningfully; other rows
        # were recomputed from their own (zero) tokens — restore untouched
        # rows by masked select
        self.cache = jax.tree.map(
            lambda new, old: _merge_slot(new, old, req.slot, self.cfg),
            cache, self.cache) if self.active else cache
        req.pos = S
        nxt = int(np.asarray(jnp.argmax(logits[req.slot])))
        req.out = [nxt]

    def submit(self, prompt, max_new=16) -> Optional[int]:
        if not self.free_slots:
            return None
        sid = self.kv.admit(len(prompt))
        if sid is None:
            return None
        rid = sid
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new=max_new, sid=sid)
        req.slot = self.free_slots.pop()
        self._prefill_into_slot(req)
        self.active[rid] = req
        return rid

    def step(self) -> List[int]:
        """One lockstep decode across active slots. Returns finished rids."""
        if not self.active:
            return []
        token = np.zeros((self.slots,), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        for r in self.active.values():
            token[r.slot] = r.out[-1]
            pos[r.slot] = r.pos
        batch = {"token": jnp.asarray(token), "pos": jnp.asarray(pos)}
        if self.cfg.pos == "mrope":
            batch["positions"] = jnp.broadcast_to(
                jnp.asarray(pos)[None, :, None], (3, self.slots, 1))
        logits, self.cache = self._decode(self.params, batch, self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for rid, r in list(self.active.items()):
            if not self.kv.append_token(r.sid):
                r.done = True            # KV pool exhausted: finish early
            r.pos += 1
            r.out.append(int(nxt[r.slot]))
            if (len(r.out) >= r.max_new or r.pos >= self.smax - 1 or
                    (self.eos_id is not None and r.out[-1] == self.eos_id) or
                    r.done):
                r.done = True
                self.kv.finish(r.sid)
                self.free_slots.append(r.slot)
                finished.append(rid)
                del self.active[rid]
        return finished

    def run(self, prompts, max_new=16) -> Dict[int, List[int]]:
        """Serve a list of prompts to completion (continuous batching).
        Returns {prompt_index: generated token list}."""
        results: Dict[int, List[int]] = {}
        registry: Dict[int, tuple] = {}
        pending = list(enumerate(prompts))
        while pending or self.active:
            progressed = False
            while pending and self.free_slots:
                idx, p = pending[0]
                rid = self.submit(p, max_new)
                if rid is None:
                    break
                registry[rid] = (idx, self.active[rid])
                pending.pop(0)
                progressed = True
            fins = self.step()
            for rid in fins:
                idx, req = registry.pop(rid)
                results[idx] = req.out
            if not fins and not progressed and not self.active:
                break  # admission dead-lock (pool exhausted): stop cleanly
        return results


def _merge_slot(new, old, slot, cfg):
    """Write only ``slot``'s rows from the freshly prefilled cache. The
    batch dim is located by size (the engine picks a slot count unequal to
    other cache dims; dense/moe/ssm/encdec caches have it at dim 1, hybrid
    group caches at dim 2)."""
    B = old.shape[1] if old.ndim >= 2 else -1
    dim = None
    if old.ndim >= 2 and old.shape[1] == cfg_slots(cfg, old):
        dim = 1
    elif old.ndim >= 3 and old.shape[2] == cfg_slots(cfg, old):
        dim = 2
    if dim is None:
        return new
    idx = [slice(None)] * new.ndim
    idx[dim] = slot
    return old.at[tuple(idx)].set(new[tuple(idx)])


def cfg_slots(cfg, leaf):
    # helper indirection so _merge_slot stays shape-driven; the engine's
    # slot count is stamped on the function by ServeEngine at init
    return _merge_slot.slots


_merge_slot.slots = 0
