"""Concurrent graph query/update service over a ``repro.api.GraphStore``.

The serving analogue of the paper's Fig. 11 mixed workload, mirroring the
continuous-batching shape of ``serve.engine`` — but storage-agnostic: the
service takes ANY GraphStore (the sharded mesh engine, the single-shard
``LocalStore``, or a future backend) and only schedules. Requests enter
admission queues, the writer ingests fixed-size micro-batches through
``store.apply`` (the store pads to its static batch, so the jit cache
stays warm), and every read is pinned to the latest SEALED epoch — an O(1)
``store.capture()`` handle onto the immutable functional state. A heavy
analytics query can never observe a half-applied batch, and the writer
never waits for readers (RapidStore-style decoupling).

Scheduling per ``step()``:

1. **write phase** — up to ``write_batch`` queued edge ops ship as one
   ``OpBatch``; the sharded store's write path keeps the live state
   vertex-synced incrementally, so sealed epochs are analytics-ready;
2. **read phase** — up to ``query_batch`` queued queries are answered
   against the sealed epoch: degree queries ride ``ReadOp`` batches, any
   REGISTERED analytics (BFS / PageRank / WCC / SSSP / BC / k-hop) runs
   through ``store.analytics`` and is memoized per epoch;
3. **seal phase** — every ``seal_every`` steps the live state is published
   as the new read epoch (``store.capture()``).

Sealed epochs CHAIN: instead of discarding the analytics memo at each
seal, warm results (``AnalyticsResult`` with backend-private per-row
values) are advanced over the epoch delta by the store's incremental
engine (``analytics_advance``), falling back to scratch — with the reason
recorded — whenever the window refuses. Warm states live in an LRU
bounded by ``max_warm_states``; each pins its epoch via the store's
refcounted ``pin_epoch``/``release_epoch`` so MVCC retention plateaus
instead of growing with the write stream.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.api import AnalyticsOp, GraphStore, OpBatch, ReadOp
from repro.api.registry import analytics_spec

__all__ = ["GraphQueryService", "Query", "drive_mixed_workload"]


def drive_mixed_workload(svc: "GraphQueryService", src, dst, w, query_ids):
    """The Fig. 11 measurement protocol, shared by benchmarks and dryruns:
    prime the jit caches with one tiny step, enqueue the stream, then drain
    it with a 1:1 interleave of write micro-batches and degree reads.
    Returns (elapsed_seconds, reads_answered)."""
    svc.submit_update(src[:1], dst[:1], w[:1])
    svc.submit_query("degree", ids=query_ids)
    svc.step()
    svc.submit_update(src, dst, w)
    reads = 0
    t0 = time.perf_counter()
    while svc.pending_writes:
        svc.submit_query("degree", ids=query_ids)
        svc.step()
        reads += len(query_ids)
    return time.perf_counter() - t0, reads


@dataclasses.dataclass
class Query:
    ticket: int
    kind: str                    # 'degree' | any registered analytics name
    ids: Optional[np.ndarray] = None     # degree: queried vertex IDs
    params: Optional[dict] = None        # analytics parameters


class GraphQueryService:
    """Micro-batching reader/writer front-end over a GraphStore."""

    def __init__(self, store: GraphStore, *, write_batch: Optional[int] = None,
                 query_batch: Optional[int] = None, seal_every: int = 1,
                 max_pending: int = 65536, bfs_iters: int = 32,
                 pr_iters: int = 20, damping: float = 0.85,
                 pipeline_depth: int = 1, incremental: bool = True,
                 max_warm_states: int = 8, durable_ack: bool = True):
        self.store = store
        # durable mode: when the store is WAL-backed (repro.storage.
        # DurableStore), every write phase ends on a group-commit sync, so
        # a write is on disk before any read of the same step can observe
        # it — the service never acks state a crash could lose
        self.durable_ack = durable_ack and \
            getattr(store, "wal", None) is not None
        self.n_shards = store.n_shards
        self.write_batch = write_batch or getattr(
            store, "batch", None) or store.graph.batch
        self.query_batch = query_batch or getattr(store, "query_batch", 256)
        # micro-batches drained per write phase: one store.apply flush ships
        # up to pipeline_depth device batches back-to-back (donated
        # steady-state dispatches, a single host sync per flush) — depth 1
        # preserves the classic one-batch-per-step scheduling
        self.pipeline_depth = max(1, pipeline_depth)
        self.seal_every = seal_every
        self.max_pending = max_pending
        self.bfs_iters = bfs_iters
        self.pr_iters = pr_iters
        self.damping = damping
        # epoch-chained analytics: warm results advance across seals
        # instead of recomputing; bounded LRU + refcounted epoch pins
        self.incremental = incremental
        self.max_warm_states = max_warm_states
        self._warm = collections.OrderedDict()  # cache_key -> AnalyticsResult
        self._pins: Dict[int, list] = {}        # epoch seq -> [handle, refs]

        # sealed read epoch (immutable capture, O(1) to publish)
        self.epoch = 0
        self._sealed = store.capture()
        self._retain(self._sealed)
        self._analytics_cache: Dict = {}    # op.cache_key() -> result
        self._epoch_sync_counted = False

        self._writes = collections.deque()  # (src, dst, w) id chunks
        self._vertex_ops = collections.deque()  # (kind, ids) CRUD batches
        self.pending_writes = 0
        self._reads = collections.deque()
        self._next_ticket = 0
        self.results: Dict[int, object] = {}
        self._stats = dict(steps=0, queries_answered=0, epochs_sealed=0,
                           sync_reused=0, write_flushes=0,
                           inflight_write_batches=0, analytics_scratch=0,
                           analytics_incremental=0, warm_evictions=0,
                           vertex_ops=0, writes_rejected=0,
                           durable_syncs=0)

    @property
    def stats(self) -> dict:
        """Service counters merged with the store's — op accounting
        (ops_applied/ops_dropped, sync_runs/skips) lives on the store and
        is never shadowed here (keys are disjoint by construction).
        Admission observability for the serving tier: ``queued_write_ops``
        (ops admitted but not yet shipped) vs ``inflight_write_batches``
        (device batches the LAST flush dispatched), plus the store's own
        ``flushes``/``super_batches`` pipeline counters."""
        return {**getattr(self.store, "stats", {}), **self._stats,
                "queued_write_ops": self.pending_writes,
                "warm_states": len(self._warm),
                "retained_epochs": getattr(self.store, "retained_epochs",
                                           0)}

    # ---- admission ----
    def submit_update(self, src, dst, weight=None) -> bool:
        """Enqueue edge ops (weight 0 = delete). False = backpressure."""
        src = np.asarray(src, np.uint64)
        dst = np.asarray(dst, np.uint64)
        w = np.ones(len(src), np.float32) if weight is None \
            else np.asarray(weight, np.float32)
        if self.pending_writes + len(src) > self.max_pending:
            return False
        self._writes.append((src, dst, w))
        self.pending_writes += len(src)
        return True

    def _submit_vertex_op(self, kind: str, ids) -> bool:
        """Admission for vertex CRUD: backends that cannot route the op
        REJECT it here (``writes_rejected``) instead of crashing the
        write loop mid-step — the ShardedStore raises a typed
        ``UnsupportedOpError`` for vertex-only batches, and admission is
        where that surfaces."""
        supported = getattr(self.store, "supported_ops", None)
        if supported is not None and kind not in supported:
            self._stats["writes_rejected"] += 1
            return False
        self._vertex_ops.append((kind, np.asarray(ids, np.uint64)))
        return True

    def submit_add_vertices(self, ids) -> bool:
        """Enqueue a vertex-create batch. False = rejected (unsupported
        backend). Vertex batches flush at the START of the next write
        phase, before that phase's edge coalescing."""
        return self._submit_vertex_op("add_vertices", ids)

    def submit_delete_vertices(self, ids) -> bool:
        """Enqueue a vertex-delete batch (see ``submit_add_vertices``)."""
        return self._submit_vertex_op("delete_vertices", ids)

    def _build_op(self, q: Query) -> AnalyticsOp:
        params = dict(q.params or {})
        if q.kind == "bfs":
            params.setdefault("max_iters", self.bfs_iters)
        elif q.kind == "pagerank":
            params.setdefault("iters", self.pr_iters)
            params.setdefault("damping", self.damping)
        return AnalyticsOp(q.kind, params)

    def submit_query(self, kind: str, ids=None, **params) -> Optional[int]:
        """Enqueue a read: ``'degree'`` (needs ``ids``) or any analytics
        name in the registry (``source=``/``sources=``/knobs as kwargs).
        Returns a ticket (see ``results``) or None on backpressure."""
        # reject malformed queries at admission, not mid-step
        if kind == "degree":
            assert ids is not None, "degree query needs ids"
        else:
            spec = analytics_spec(kind)       # raises on unknown kinds
            for pname, _ in spec.dyn:
                assert pname in params, f"{kind} query needs {pname}="
        if len(self._reads) >= self.max_pending:
            return None
        t = self._next_ticket
        self._next_ticket += 1
        self._reads.append(Query(
            ticket=t, kind=kind,
            ids=None if ids is None else np.asarray(ids, np.uint64),
            params=params or None))
        return t

    # ---- epochs ----
    def _retain(self, ep):
        """Refcounted epoch pin: the first reference registers the epoch
        in the store's MVCC retention (``pin_epoch``); equal-seq captures
        (seals with no writes between) share one pin."""
        if ep is None:
            return
        slot = self._pins.get(ep.seq)
        if slot is None:
            self._pins[ep.seq] = [ep, 1]
            pin = getattr(self.store, "pin_epoch", None)
            if pin is not None:
                pin(ep)
        else:
            slot[1] += 1

    def _release(self, ep):
        if ep is None:
            return
        slot = self._pins.get(ep.seq)
        if slot is None:
            return
        slot[1] -= 1
        if slot[1] == 0:
            del self._pins[ep.seq]
            rel = getattr(self.store, "release_epoch", None)
            if rel is not None:
                rel(slot[0])

    def seal_epoch(self) -> int:
        """Publish the live state as the read epoch. O(1): functional
        states are immutable, so sealing is a capture, not a copy. The
        per-epoch value memo resets; WARM analytics states survive the
        seal and advance over the delta on their next query."""
        prev = self._sealed
        self._sealed = self.store.capture()
        self._retain(self._sealed)
        self._release(prev)
        self._analytics_cache = {}
        self._epoch_sync_counted = False
        self.epoch += 1
        self._stats["epochs_sealed"] += 1
        return self.epoch

    @property
    def epoch_lag(self) -> int:
        """Operations ingested since the read epoch was sealed (staleness
        bound a reader observes)."""
        return self.store.clock() - self.store.clock(at=self._sealed)

    # ---- scheduling ----
    def _write_phase(self):
        wrote = False
        while self._vertex_ops:
            kind, ids = self._vertex_ops.popleft()
            try:
                self.store.apply(OpBatch(kind=kind, ids=ids))
                self._stats["vertex_ops"] += 1
                wrote = True
            except NotImplementedError:      # raced past admission
                self._stats["writes_rejected"] += 1
        if not self._writes:
            if wrote:
                self._durable_sync()
            return
        B = self.write_batch * self.pipeline_depth
        parts, need = [], B
        while self._writes and need > 0:
            s, d, w = self._writes[0]
            if len(w) <= need:
                parts.append(self._writes.popleft())
                need -= len(w)
            else:
                parts.append((s[:need], d[:need], w[:need]))
                self._writes[0] = (s[need:], d[need:], w[need:])
                need = 0
        take = B - need
        self.pending_writes -= take
        self.store.apply(OpBatch.edges(
            np.concatenate([p[0] for p in parts]),
            np.concatenate([p[1] for p in parts]),
            np.concatenate([p[2] for p in parts])))
        self._stats["write_flushes"] += 1
        self._stats["inflight_write_batches"] = \
            (take + self.write_batch - 1) // self.write_batch
        self._durable_sync()

    def _durable_sync(self):
        """End-of-write-phase group-commit boundary in durable mode: the
        WAL records of this phase's applies are fsynced before any read
        (or caller ack) can observe their effects."""
        if self.durable_ack:
            self.store.sync()
            self._stats["durable_syncs"] += 1

    def _remember(self, key, res):
        """Install ``res`` as the warm chain entry for ``key`` (LRU,
        epoch-pinned); evictions release their pins so retention
        plateaus at ``max_warm_states`` + the sealed epoch."""
        old = self._warm.pop(key, None)
        if old is not None:
            self._release(old.handle)
        if res.raw is None or res.handle is None:
            return                      # nothing advanceable to keep
        self._warm[key] = res
        self._retain(res.handle)
        while len(self._warm) > self.max_warm_states:
            _, ev = self._warm.popitem(last=False)
            self._release(ev.handle)
            self._stats["warm_evictions"] += 1

    def _answer_analytics(self, q: Query):
        op = self._build_op(q)
        key = op.cache_key()
        if key in self._analytics_cache:
            return self._analytics_cache[key]
        if not self._epoch_sync_counted:
            # the sharded write path keeps the live state registered
            # incrementally, so the sealed capture is reused as the
            # analytics-ready state — no per-epoch sync recompute
            if getattr(self.store, "sync_incremental", False):
                self._stats["sync_reused"] += 1
            self._epoch_sync_counted = True
        if self.incremental and hasattr(self.store, "analytics_advance"):
            res = self.store.analytics_advance(op, self._warm.get(key),
                                               self._sealed)
        elif hasattr(self.store, "analytics_result"):
            res = self.store.analytics_result(op, at=self._sealed)
        else:           # minimal backend: plain value, no warm chain
            val = self.store.analytics(op, at=self._sealed)
            self._analytics_cache[key] = val
            return val
        mode = "analytics_incremental" if res.mode == "incremental" \
            else "analytics_scratch"
        self._stats[mode] += 1
        if self.incremental:
            self._remember(key, res)
        self._analytics_cache[key] = res.value
        return res.value

    def _read_phase(self):
        served = 0
        while self._reads:
            q = self._reads[0]
            # a cold analytics run fills the read budget; a memo hit on the
            # sealed epoch is nearly free and never deferred to a new epoch
            warm = q.kind != "degree" and \
                self._build_op(q).cache_key() in self._analytics_cache
            if served >= self.query_batch and not warm:
                break
            self._reads.popleft()
            if q.kind == "degree":
                self.results[q.ticket] = self.store.read(
                    ReadOp("degree", ids=q.ids), at=self._sealed)
                served += max(1, len(q.ids))
            else:
                self.results[q.ticket] = self._answer_analytics(q)
                served += 1 if warm else self.query_batch
            self._stats["queries_answered"] += 1

    def step(self):
        """One mixed read/write scheduling round (Fig. 11 concurrency):
        ingest a write micro-batch, answer reads against the sealed epoch,
        then seal if due."""
        self._write_phase()
        self._read_phase()
        self._stats["steps"] += 1
        if self.seal_every and self._stats["steps"] % self.seal_every == 0:
            self.seal_epoch()

    def claim(self, ticket: int):
        """Pop a finished query's answer — bounds result retention for a
        long-running service. KeyError if the ticket is unanswered."""
        return self.results.pop(ticket)

    def run(self, max_steps: int = 10_000):
        """Drive scheduling rounds until both queues drain (raises if
        ``max_steps`` is exhausted first — results are never silently
        partial), then seal so queries admitted next observe every write."""
        while (self._writes or self._vertex_ops or self._reads) \
                and max_steps > 0:
            self.step()
            max_steps -= 1
        if self._writes or self._vertex_ops or self._reads:
            raise RuntimeError(
                f"run(): queues not drained ({self.pending_writes} write "
                f"ops, {len(self._reads)} reads still pending)")
        self.seal_epoch()
        return self.results
