"""Concurrent graph query/update service over the sharded RadixGraph engine.

The serving analogue of the paper's Fig. 11 mixed workload, mirroring the
continuous-batching shape of ``serve.engine``: requests enter admission
queues, the writer ingests fixed-size micro-batches through the distributed
engine (one fused route->exchange->apply program per step), and every read is
pinned to the latest SEALED epoch — an immutable functional state published
by ``seal_epoch()``. Because states are pure pytrees, sealing is O(1)
(a reference), a heavy analytics query can never observe a half-applied
batch, and the writer never waits for readers (RapidStore-style decoupling).

Scheduling per ``step()``:

1. **write phase** — up to ``write_batch`` queued edge ops are padded into
   one static-shape batch and applied (reuses the jit cache every step);
   when the batch created vertices, an INCREMENTAL vertex sync (only rows
   allocated since the last sync, compacted exchange with dense fallback)
   registers them at their owners — so sealed epochs are always
   analytics-ready and ``_synced_sealed`` reuses the sealed reference
   instead of recomputing the full registration per epoch;
2. **read phase** — up to ``query_batch`` queued queries are answered against
   the sealed epoch: degree queries ride one batched owner-routed lookup,
   BFS / PageRank run the distributed level-synchronous kernels on a lazily
   vertex-synced copy of the sealed state and are memoized per epoch;
3. **seal phase** — every ``seal_every`` steps the live state is published
   as the new read epoch.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import edgepool as ep
from repro.core.keys import pack_keys
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import optimize_sort
from repro.dist.graph_engine import (collect_owner_values, make_apply_edges,
                                     make_bfs, make_khop_counts,
                                     make_pagerank, make_sharded_state,
                                     make_sync_vertices)

__all__ = ["GraphQueryService", "Query", "drive_mixed_workload"]


def drive_mixed_workload(svc: "GraphQueryService", src, dst, w, query_ids):
    """The Fig. 11 measurement protocol, shared by benchmarks and dryruns:
    prime the jit caches with one tiny step, enqueue the stream, then drain
    it with a 1:1 interleave of write micro-batches and degree reads.
    Returns (elapsed_seconds, reads_answered)."""
    svc.submit_update(src[:1], dst[:1], w[:1])
    svc.submit_query("degree", ids=query_ids)
    svc.step()
    svc.submit_update(src, dst, w)
    reads = 0
    t0 = time.perf_counter()
    while svc.pending_writes:
        svc.submit_query("degree", ids=query_ids)
        svc.step()
        reads += len(query_ids)
    return time.perf_counter() - t0, reads


@dataclasses.dataclass
class Query:
    ticket: int
    kind: str                      # 'degree' | 'bfs' | 'pagerank'
    ids: Optional[np.ndarray] = None     # degree: queried vertex IDs
    source: Optional[int] = None         # bfs: source vertex ID


class GraphQueryService:
    """Micro-batching reader/writer front-end for the sharded graph engine."""

    def __init__(self, n_shards: int = 1, *, n_per_shard: int = 8192,
                 expected_n: int = 4096, key_bits: int = 32,
                 pool_blocks: int = 16384, block_size: int = 16,
                 k_max: int = 128, dmax: int = 2048,
                 write_batch: int = 1024, query_batch: int = 256,
                 seal_every: int = 1, max_pending: int = 65536,
                 m_cap: Optional[int] = None, bfs_iters: int = 32,
                 pr_iters: int = 20, damping: float = 0.85,
                 undirected: bool = False, axis: str = "data",
                 sync_incremental: bool = True,
                 sync_budget: Optional[int] = None,
                 frontier_budget: Optional[int] = None):
        assert write_batch % n_shards == 0 and query_batch % n_shards == 0, \
            "micro-batch sizes must be divisible by the shard count"
        from jax.sharding import AxisType
        self.n_shards = n_shards
        self.key_bits = key_bits
        self.write_batch = write_batch
        self.query_batch = query_batch
        self.seal_every = seal_every
        self.max_pending = max_pending
        self.undirected = undirected
        self.sync_incremental = sync_incremental
        self.mesh = jax.make_mesh((n_shards,), (axis,),
                                  devices=jax.devices()[:n_shards],
                                  axis_types=(AxisType.Auto,))
        cfg = optimize_sort(expected_n, key_bits, 5)
        self.sspec = SortSpec.from_config(cfg, n_per_shard)
        self.pspec = ep.PoolSpec(n_blocks=pool_blocks, block_size=block_size,
                                 k_max=k_max, dmax=dmax)
        m_cap = m_cap or self.pspec.capacity_entries
        self.m_cap = m_cap
        self.state = make_sharded_state(self.sspec, self.pspec, n_shards,
                                        n_per_shard)
        self._apply = jax.jit(make_apply_edges(self.sspec, self.pspec,
                                               self.mesh, axis))
        self._degree = jax.jit(make_khop_counts(self.sspec, self.pspec,
                                                self.mesh, axis))
        self._sync = jax.jit(make_sync_vertices(self.sspec, self.pspec,
                                                self.mesh, axis))
        if sync_budget is None:
            # a write step creates at most 2 * write_batch rows globally
            sync_budget = min(n_per_shard,
                              2 * write_batch // n_shards + 64)
        self._sync_inc = jax.jit(make_sync_vertices(
            self.sspec, self.pspec, self.mesh, axis, budget=sync_budget,
            incremental=True))
        self._bfs = jax.jit(make_bfs(self.sspec, self.pspec, self.mesh, axis,
                                     m_cap, max_iters=bfs_iters,
                                     frontier_budget=frontier_budget))
        self._pagerank = jax.jit(make_pagerank(self.sspec, self.pspec,
                                               self.mesh, axis,
                                               m_cap, iters=pr_iters,
                                               damping=damping,
                                               frontier_budget=frontier_budget))

        # sealed read epoch (immutable pytree reference, O(1) to publish)
        self.epoch = 0
        self._sealed = self.state
        self._sealed_synced = None          # lazy vertex-synced copy
        self._analytics_cache: Dict = {}    # (kind, arg) -> result, per epoch

        # vertex-creation tracking for the incremental sync: rows allocated
        # on each shard as of the last sync (vertex rows are never recycled
        # here — the service has no vertex deletes — so growth of num_rows
        # is exactly "vertices were created since")
        self._synced_rows = np.zeros((n_shards,), np.int32)

        self._writes = collections.deque()  # (src_keys, dst_keys, w) chunks
        self.pending_writes = 0
        self._reads = collections.deque()
        self._next_ticket = 0
        self.results: Dict[int, object] = {}
        self.stats = dict(steps=0, ops_applied=0, ops_dropped=0,
                          queries_answered=0, epochs_sealed=0,
                          sync_runs=0, sync_skips=0, sync_reused=0)

    # ---- admission ----
    def _keys(self, ids) -> np.ndarray:
        return np.asarray(pack_keys(np.asarray(ids, np.uint64),
                                    self.key_bits))

    def submit_update(self, src, dst, weight=None) -> bool:
        """Enqueue edge ops (weight 0 = delete). False = backpressure."""
        src = np.asarray(src, np.uint64)
        dst = np.asarray(dst, np.uint64)
        w = np.ones(len(src), np.float32) if weight is None \
            else np.asarray(weight, np.float32)
        if self.undirected:
            s2 = np.empty(2 * len(src), np.uint64)
            d2 = np.empty_like(s2)
            w2 = np.empty(2 * len(src), np.float32)
            s2[0::2], s2[1::2] = src, dst
            d2[0::2], d2[1::2] = dst, src
            w2[0::2], w2[1::2] = w, w
            src, dst, w = s2, d2, w2
        if self.pending_writes + len(src) > self.max_pending:
            return False
        self._writes.append((self._keys(src), self._keys(dst), w))
        self.pending_writes += len(src)
        return True

    def submit_query(self, kind: str, ids=None, source=None) -> Optional[int]:
        """Enqueue a read. Returns a ticket (see ``results``) or None on
        backpressure."""
        assert kind in ("degree", "bfs", "pagerank"), kind
        # reject malformed queries at admission, not mid-step
        assert kind != "degree" or ids is not None, "degree query needs ids"
        assert kind != "bfs" or source is not None, "bfs query needs a source"
        if len(self._reads) >= self.max_pending:
            return None
        t = self._next_ticket
        self._next_ticket += 1
        self._reads.append(Query(
            ticket=t, kind=kind,
            ids=None if ids is None else np.asarray(ids, np.uint64),
            source=None if source is None else int(source)))
        return t

    # ---- epochs ----
    def seal_epoch(self) -> int:
        """Publish the live state as the read epoch. O(1): functional states
        are immutable, so sealing is a reference, not a copy."""
        self._sealed = self.state
        self._sealed_synced = None
        self._analytics_cache = {}
        self.epoch += 1
        self.stats["epochs_sealed"] += 1
        return self.epoch

    @property
    def epoch_lag(self) -> int:
        """Operations ingested since the read epoch was sealed (staleness
        bound a reader observes)."""
        live = int(np.asarray(self.state.pool.clock)[0])
        sealed = int(np.asarray(self._sealed.pool.clock)[0])
        return live - sealed

    def _maybe_sync_live(self):
        """Eager incremental vertex sync, run right after a write
        micro-batch: only rows created since the last sync are registered at
        their owner shards (compacted exchange with dense fallback), so
        every sealed epoch is already analytics-ready. Skipped — no
        collective at all — when the batch created no vertices."""
        rows = np.asarray(self.state.vt.num_rows)
        if np.array_equal(rows, self._synced_rows):
            self.stats["sync_skips"] += 1
            return
        self.state = self._sync_inc(self.state,
                                    jnp.asarray(self._synced_rows))
        self._synced_rows = np.asarray(self.state.vt.num_rows)
        self.stats["sync_runs"] += 1

    def _synced_sealed(self):
        if self._sealed_synced is None:
            if self.sync_incremental:
                # the write path keeps the live state registered as it goes,
                # so sealing needs NO per-epoch recompute: the sealed
                # reference is reused as the synced state (ROADMAP item)
                self.stats["sync_reused"] += 1
                self._sealed_synced = self._sealed
            else:
                self.stats["sync_runs"] += 1
                self._sealed_synced = self._sync(self._sealed)
        return self._sealed_synced

    # ---- scheduling ----
    def _write_phase(self):
        if not self._writes:
            return
        B = self.write_batch
        parts, need = [], B
        while self._writes and need > 0:
            sk, dk, w = self._writes[0]
            if len(w) <= need:
                parts.append(self._writes.popleft())
                need -= len(w)
            else:
                parts.append((sk[:need], dk[:need], w[:need]))
                self._writes[0] = (sk[need:], dk[need:], w[need:])
                need = 0
        take = B - need
        self.pending_writes -= take
        sk = np.zeros((B, 2), np.uint32)
        dk = np.zeros((B, 2), np.uint32)
        w = np.zeros((B,), np.float32)
        mask = np.zeros((B,), bool)
        sk[:take] = np.concatenate([p[0] for p in parts])
        dk[:take] = np.concatenate([p[1] for p in parts])
        w[:take] = np.concatenate([p[2] for p in parts])
        mask[:take] = True
        self.state, dropped = self._apply(self.state, jnp.asarray(sk),
                                          jnp.asarray(dk), jnp.asarray(w),
                                          jnp.asarray(mask))
        self.stats["ops_applied"] += take
        self.stats["ops_dropped"] += int(np.asarray(dropped).sum())
        if self.sync_incremental:
            self._maybe_sync_live()

    def _answer_degree(self, q: Query):
        Q = self.query_batch
        out = np.zeros((len(q.ids),), np.int32)
        keys = self._keys(q.ids)
        for lo in range(0, len(q.ids), Q):
            chunk = keys[lo:lo + Q]
            buf = np.zeros((Q, 2), np.uint32)
            buf[:len(chunk)] = chunk
            cnt = np.asarray(self._degree(self._sealed, jnp.asarray(buf)))
            out[lo:lo + len(chunk)] = cnt[:len(chunk)]
        return out

    def _answer_analytics(self, q: Query):
        key = (q.kind, q.source)
        if key not in self._analytics_cache:
            synced = self._synced_sealed()
            if q.kind == "bfs":
                sk = self._keys(np.array([q.source], np.uint64))[0]
                depth = self._bfs(synced, jnp.asarray(sk))
                val = collect_owner_values(synced, np.asarray(depth),
                                           self.n_shards)
            else:
                pr = self._pagerank(synced)
                val = collect_owner_values(synced, np.asarray(pr),
                                           self.n_shards)
            self._analytics_cache[key] = val
        return self._analytics_cache[key]

    def _read_phase(self):
        served = 0
        while self._reads:
            q = self._reads[0]
            # a cold analytics run fills the read budget; a memo hit on the
            # sealed epoch is nearly free and never deferred to a new epoch
            warm = q.kind != "degree" and \
                (q.kind, q.source) in self._analytics_cache
            if served >= self.query_batch and not warm:
                break
            self._reads.popleft()
            if q.kind == "degree":
                self.results[q.ticket] = self._answer_degree(q)
                served += max(1, len(q.ids))
            else:
                self.results[q.ticket] = self._answer_analytics(q)
                served += 1 if warm else self.query_batch
            self.stats["queries_answered"] += 1

    def step(self):
        """One mixed read/write scheduling round (Fig. 11 concurrency):
        ingest a write micro-batch, answer reads against the sealed epoch,
        then seal if due."""
        self._write_phase()
        self._read_phase()
        self.stats["steps"] += 1
        if self.seal_every and self.stats["steps"] % self.seal_every == 0:
            self.seal_epoch()

    def claim(self, ticket: int):
        """Pop a finished query's answer — bounds result retention for a
        long-running service. KeyError if the ticket is unanswered."""
        return self.results.pop(ticket)

    def run(self, max_steps: int = 10_000):
        """Drive scheduling rounds until both queues drain (raises if
        ``max_steps`` is exhausted first — results are never silently
        partial), then seal so queries admitted next observe every write."""
        while (self._writes or self._reads) and max_steps > 0:
            self.step()
            max_steps -= 1
        if self._writes or self._reads:
            raise RuntimeError(
                f"run(): queues not drained ({self.pending_writes} write "
                f"ops, {len(self._reads)} reads still pending)")
        self.seal_epoch()
        return self.results
