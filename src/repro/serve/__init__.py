from .radix_kv import RadixKVManager
from .engine import ServeEngine

__all__ = ["RadixKVManager", "ServeEngine"]
