from .radix_kv import RadixKVManager
from .engine import ServeEngine
from .graph_service import GraphQueryService

__all__ = ["RadixKVManager", "ServeEngine", "GraphQueryService"]
