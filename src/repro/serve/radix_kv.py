"""RadixKV — the paper's snapshot-log lifecycle transplanted onto paged KV
cache blocks.

Mapping (edge array -> KV extent):
  vertex            -> active sequence
  edge block        -> KV block (``block_tokens`` positions)
  log append O(1)   -> per-token block append from the bump allocator
  compaction (2d)   -> defragmentation: live sequences relocated to
                       contiguous extents, freed/finished blocks reclaimed
  free-slot queue   -> finished sequences recycled at defrag epochs only
                       (same dangling-reference safety argument as §3.1)

The manager is host-side (admission control / block tables); the device-side
cache is the contiguous-per-sequence layout the models already use, plus a
``gather`` relocation plan emitted at defrag. Amortized O(1) blocks-touched
per decoded token, mirroring Theorem 2.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class Sequence:
    sid: int
    start_block: int
    n_blocks: int
    tokens: int
    finished: bool = False


@dataclass
class RadixKVManager:
    total_blocks: int
    block_tokens: int = 16
    defrag_threshold: float = 0.5   # defrag when garbage > half the pool

    next_block: int = 0
    garbage_blocks: int = 0
    seqs: Dict[int, Sequence] = field(default_factory=dict)
    _next_sid: int = 0
    defrags: int = 0
    overflow: int = 0

    # ---- paper-lifecycle operations ----
    def admit(self, prompt_tokens: int) -> Optional[int]:
        """Admit a sequence: allocate a 2x extent (snapshot = prompt blocks,
        log = equal headroom — the paper's cap = 2d discipline)."""
        need = max(1, -(-prompt_tokens // self.block_tokens))
        blocks = 2 * need
        if not self._ensure(blocks):
            self.overflow += 1
            return None
        s = Sequence(self._next_sid, self.next_block, blocks, prompt_tokens)
        self.next_block += blocks
        self.seqs[s.sid] = s
        self._next_sid += 1
        return s.sid

    def append_token(self, sid: int) -> bool:
        """O(1) log append; on extent exhaustion re-extent at 2x (the
        compaction-growth path; relocation cost amortizes per Theorem 2)."""
        s = self.seqs[sid]
        s.tokens += 1
        if s.tokens <= s.n_blocks * self.block_tokens:
            return True
        live = -(-s.tokens // self.block_tokens)
        blocks = 2 * live
        if not self._ensure(blocks):
            self.overflow += 1
            s.tokens -= 1
            return False
        self.garbage_blocks += s.n_blocks
        s.start_block = self.next_block
        s.n_blocks = blocks
        self.next_block += blocks
        return True

    def finish(self, sid: int):
        s = self.seqs[sid]
        s.finished = True
        self.garbage_blocks += s.n_blocks

    def _ensure(self, blocks: int) -> bool:
        if self.next_block + blocks <= self.total_blocks:
            return True
        if self.garbage_blocks > 0:   # any reclaim might make it fit
            self.defrag()
        return self.next_block + blocks <= self.total_blocks

    def defrag(self) -> List[Tuple[int, int, int]]:
        """Compact live extents to the front (vertex-ordered relocation).
        Returns the relocation plan [(old_start, new_start, n_blocks)] the
        device cache applies as one gather."""
        plan = []
        cursor = 0
        for sid in sorted(self.seqs):
            s = self.seqs[sid]
            if s.finished:
                continue
            live = max(1, -(-s.tokens // self.block_tokens))
            blocks = 2 * live
            plan.append((s.start_block, cursor, min(s.n_blocks, blocks)))
            s.start_block = cursor
            s.n_blocks = blocks
            cursor += blocks
        self.seqs = {k: v for k, v in self.seqs.items() if not v.finished}
        self.next_block = cursor
        self.garbage_blocks = 0
        self.defrags += 1
        return plan

    # ---- introspection ----
    @property
    def live_blocks(self) -> int:
        return sum(s.n_blocks for s in self.seqs.values() if not s.finished)

    @property
    def utilization(self) -> float:
        return self.live_blocks / max(self.total_blocks, 1)
