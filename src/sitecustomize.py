"""Interpreter-startup jax compatibility shim.

Active in any process with ``src`` on PYTHONPATH (the repo's canonical
``PYTHONPATH=src python -m ...`` invocation): CPython's ``site`` module
imports ``sitecustomize`` from sys.path at startup.

jax 0.4.37 (this container) predates two APIs the launch/benchmark/test
entry points use before importing anything from ``repro``:

* ``jax.sharding.AxisType`` (Auto / Explicit / Manual enum)
* the ``axis_types=`` kwarg of ``jax.make_mesh``

On 0.4.37 every mesh axis already behaves as Auto under jit, so the shim
provides the enum and accepts-and-drops the kwarg; on jax versions that ship
the real API it is a no-op. Importing jax here does NOT initialize the XLA
backend, so entry points that set ``XLA_FLAGS`` (placeholder device counts)
before first device use keep working.

Set ``REPRO_NO_JAX_SHIM=1`` to disable.
"""
import os


def _install():
    try:
        import jax
        import jax.sharding as jsh
    except Exception:
        return

    if not hasattr(jsh, "AxisType"):
        import enum

        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jsh.AxisType = AxisType

    import inspect
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        return
    if "axis_types" not in params:
        import functools

        orig = jax.make_mesh

        @functools.wraps(orig)
        def make_mesh(axis_shapes, axis_names, *args, axis_types=None,
                      **kwargs):
            return orig(axis_shapes, axis_names, *args, **kwargs)

        jax.make_mesh = make_mesh


if not os.environ.get("REPRO_NO_JAX_SHIM"):
    _install()
del os, _install
