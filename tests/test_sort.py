"""SORT index (JAX) vs a Python dict oracle — property-based."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sort as S
from repro.core import vertex_table as VT
from repro.core.keys import pack_keys, unpack_keys
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import optimize_sort


def make(n_max=512, key_bits=32, layers=5, n=200):
    spec = SortSpec.from_config(optimize_sort(n, key_bits, layers), n_max)
    return spec, S.make_sort(spec)


def test_insert_lookup_roundtrip(rng):
    spec, st = make()
    ids = rng.choice(2 ** 32, 300, replace=False).astype(np.uint64)
    offs = jnp.arange(300, dtype=jnp.int32)
    st = S.insert_mappings(spec, st, pack_keys(ids, 32), offs,
                           jnp.ones(300, bool))
    got = S.lookup(spec, st, pack_keys(ids, 32))
    assert np.array_equal(np.asarray(got), np.arange(300))
    missing = rng.choice(2 ** 32, 100).astype(np.uint64)
    missing = np.setdiff1d(missing, ids)
    got = S.lookup(spec, st, pack_keys(missing, 32))
    assert np.all(np.asarray(got) == -1)
    assert int(st.overflow) == 0


def test_duplicate_keys_one_batch_share_nodes(rng):
    """Two identical new keys in one batch must produce ONE path."""
    spec, st = make()
    ids = np.array([42, 42, 7, 7, 7], dtype=np.uint64)
    offs = jnp.asarray([5, 5, 9, 9, 9], jnp.int32)
    st = S.insert_mappings(spec, st, pack_keys(ids, 32), offs,
                           jnp.ones(5, bool))
    got = np.asarray(S.lookup(spec, st, pack_keys(np.array([42, 7],
                                                           np.uint64), 32)))
    assert got.tolist() == [5, 9]


def test_delete_then_reinsert(rng):
    spec, st = make()
    ids = rng.choice(2 ** 32, 64, replace=False).astype(np.uint64)
    st = S.insert_mappings(spec, st, pack_keys(ids, 32),
                           jnp.arange(64, dtype=jnp.int32),
                           jnp.ones(64, bool))
    st, offs, found = S.delete_keys(spec, st, pack_keys(ids[:32], 32),
                                    jnp.ones(32, bool))
    assert np.all(np.asarray(found))
    assert np.all(np.asarray(S.lookup(spec, st, pack_keys(ids[:32], 32))) == -1)
    assert np.all(np.asarray(S.lookup(spec, st, pack_keys(ids[32:], 32))) >= 0)
    st = S.insert_mappings(spec, st, pack_keys(ids[:4], 32),
                           jnp.asarray([100, 101, 102, 103], jnp.int32),
                           jnp.ones(4, bool))
    got = np.asarray(S.lookup(spec, st, pack_keys(ids[:4], 32)))
    assert got.tolist() == [100, 101, 102, 103]


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 2 ** 20 - 1), min_size=1, max_size=120),
       st.sampled_from([20, 32]))
def test_vs_dict_oracle(xs, key_bits):
    spec, stt = make(key_bits=key_bits, n=64)
    oracle = {}
    ids = np.array(xs, dtype=np.uint64)
    B = len(ids)
    offs = jnp.arange(B, dtype=jnp.int32)
    # duplicates in batch: LAST write wins in the oracle; our scatter writes
    # identical offsets only for dup NEW keys, so feed unique offsets per
    # unique key (first occurrence's offset) like the vertex table does
    first_off = {}
    offv = np.zeros(B, np.int32)
    for i, v in enumerate(xs):
        first_off.setdefault(v, i)
        offv[i] = first_off[v]
        oracle[v] = first_off[v]
    stt = S.insert_mappings(spec, stt, pack_keys(ids, key_bits),
                            jnp.asarray(offv), jnp.ones(B, bool))
    got = np.asarray(S.lookup(spec, stt, pack_keys(ids, key_bits)))
    for i, v in enumerate(xs):
        assert got[i] == oracle[v]


def test_vertex_table_free_ring_reuse(rng):
    spec, stt = make()
    vt = VT.make_vertex_table(512)
    ids = rng.choice(2 ** 32, 40, replace=False).astype(np.uint64)
    stt, vt, off, created = VT.ensure_vertices(spec, stt, vt,
                                               pack_keys(ids, 32),
                                               jnp.ones(40, bool))
    assert int(np.sum(np.asarray(created))) == 40
    assert len(set(np.asarray(off).tolist())) == 40
    # duplicate IDs in one batch share an offset
    dup = np.array([ids[0], ids[0], 12345], np.uint64)
    stt, vt, off2, created2 = VT.ensure_vertices(spec, stt, vt,
                                                 pack_keys(dup, 32),
                                                 jnp.ones(3, bool))
    o = np.asarray(off2)
    assert o[0] == o[1] == np.asarray(off)[0]
    assert np.asarray(created2).tolist() == [False, False, True]
