"""Deterministic stand-in for the subset of the ``hypothesis`` API this
repo's tests use (``given``, ``settings``, ``strategies.integers/lists/
tuples/sampled_from/floats/booleans``).

Installed into ``sys.modules["hypothesis"]`` by ``conftest.py`` ONLY when
the real hypothesis (declared in pyproject's test extras) is not importable,
so property tests still execute — with seeded pseudo-random examples instead
of adaptive search/shrinking — rather than failing at collection on a
missing optional dep.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

__version__ = "0.0.mini"


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda r: seq[r.randrange(len(seq))])


def tuples(*strats):
    return _Strategy(lambda r: tuple(s._draw(r) for s in strats))


def lists(elements, min_size=0, max_size=None):
    def draw(r):
        hi = max_size if max_size is not None else min_size + 20
        return [elements._draw(r) for _ in range(r.randint(min_size, hi))]
    return _Strategy(draw)


def booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


strategies = types.ModuleType("hypothesis.strategies")
for _n in ("integers", "sampled_from", "tuples", "lists", "booleans",
           "floats"):
    setattr(strategies, _n, globals()[_n])


def settings(max_examples=20, deadline=None, **_kw):
    def deco(fn):
        fn._mini_hyp_max_examples = max_examples
        return fn
    return deco


def assume(condition):
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


def given(*gargs, **gkwargs):
    """Positional strategies bind to the function's LAST positional params
    (hypothesis fills from the right); keyword strategies bind by name. The
    wrapper keeps the remaining params visible so pytest fixtures/parametrize
    compose."""
    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        strats = dict(zip(names[len(names) - len(gargs):], gargs))
        strats.update(gkwargs)
        remaining = [p for n, p in sig.parameters.items() if n not in strats]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n_ex = getattr(wrapper, "_mini_hyp_max_examples", 20)
            rnd = random.Random(zlib.adler32(fn.__qualname__.encode()))
            for i in range(n_ex):
                drawn = {k: s._draw(rnd) for k, s in strats.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except _Unsatisfied:
                    continue
                except BaseException:
                    print(f"mini-hypothesis falsifying example "
                          f"({i + 1}/{n_ex}): {drawn!r}")
                    raise

        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper
    return deco
