"""Pallas kernels (interpret mode) vs pure-jnp oracles — shape/dtype sweep."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sort as S
from repro.core.keys import pack_keys
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import optimize_sort
from repro.kernels import ref as R
from repro.kernels.append import append_pallas, append_tile_rows
from repro.kernels.compact import compact_rows_pallas, defrag_rows_pallas
from repro.kernels.frontier import frontier_pallas
from repro.kernels.sort_lookup import sort_lookup_pallas


@pytest.mark.parametrize("K,D", [(1, 8), (3, 16), (5, 64), (2, 128)])
@pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16])
def test_compact_kernel_sweep(K, D, wdtype, rng):
    n_cap = 64
    dst = rng.integers(-1, n_cap, (K, D)).astype(np.int32)
    w = np.round(rng.uniform(0, 2, (K, D))).astype(np.float32)
    ts = rng.permutation(K * D).reshape(K, D).astype(np.int32)
    size = rng.integers(0, D + 1, (K,)).astype(np.int32)
    a = R.compact_rows_ref(jnp.asarray(dst), jnp.asarray(w, wdtype),
                           jnp.asarray(ts), jnp.asarray(size))
    b = compact_rows_pallas(jnp.asarray(dst), jnp.asarray(w, wdtype),
                            jnp.asarray(ts), jnp.asarray(size), n_cap=n_cap)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31), st.integers(1, 64))
def test_compact_kernel_read_ts(seed, size_hint):
    rng = np.random.default_rng(seed)
    K, D = 2, 32
    dst = rng.integers(-1, 32, (K, D)).astype(np.int32)
    w = np.round(rng.uniform(0, 2, (K, D))).astype(np.float32)
    ts = rng.permutation(K * D).reshape(K, D).astype(np.int32)
    size = np.minimum(size_hint, D) * np.ones(K, np.int32)
    rt = int(rng.integers(0, K * D))
    a = R.compact_rows_ref(*map(jnp.asarray, (dst, w, ts, size)), read_ts=rt)
    b = compact_rows_pallas(*map(jnp.asarray, (dst, w, ts, size)),
                            read_ts=rt, n_cap=64)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("n,tile", [(128, 64), (500, 128)])
def test_sort_lookup_kernel(n, tile, rng):
    cfg = optimize_sort(n, 32, 5)
    spec = SortSpec.from_config(cfg, 2 * n)
    stt = S.make_sort(spec)
    ids = rng.choice(2 ** 32, n, replace=False).astype(np.uint64)
    stt = S.insert_mappings(spec, stt, pack_keys(ids, 32),
                            jnp.arange(n, dtype=jnp.int32),
                            jnp.ones(n, bool))
    q = np.concatenate([ids, rng.choice(2 ** 32, 2 * tile - n % tile or tile)
                        .astype(np.uint64)])
    q = q[: (len(q) // tile) * tile]
    qk = pack_keys(q, 32)
    a = R.sort_lookup_ref(stt.pools, stt.counts, qk,
                          fanout_bits=spec.fanout_bits,
                          bit_offsets=spec.bit_offsets)
    b = sort_lookup_pallas(stt.pools, stt.counts, qk,
                           fanout_bits=spec.fanout_bits,
                           bit_offsets=spec.bit_offsets, tile=tile)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("K,D", [(1, 8), (4, 16), (3, 64), (2, 128)])
def test_defrag_rows_kernel_sweep(K, D, rng):
    """The defrag row compactor (bitmap + prefix-popcount ranks) must match
    its oracle bit-exactly: dedup by highest occupied position, tombstones
    dropped, survivors emitted by ascending destination."""
    n_cap = 64
    dst = rng.integers(-1, n_cap, (K, D)).astype(np.int32)
    w = np.round(rng.uniform(0, 2, (K, D))).astype(np.float32)
    ts = rng.permutation(K * D).reshape(K, D).astype(np.int32)
    size = rng.integers(0, D + 1, (K,)).astype(np.int32)
    a = R.defrag_rows_ref(*map(jnp.asarray, (dst, w, ts, size)))
    b = defrag_rows_pallas(*map(jnp.asarray, (dst, w, ts, size)),
                           n_cap=n_cap)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_defrag_rows_keep_all_orders_by_dst_then_pos(rng):
    """'grow' mode keeps every occupied entry (dups + tombstones), grouped
    by destination in position order, and still reports live pairs."""
    dst = np.array([[3, 1, 3, 2, 1, -1]], np.int32)
    w = np.array([[1.0, 0.0, 2.0, 1.0, 5.0, 9.0]], np.float32)
    ts = np.array([[1, 2, 3, 4, 5, 6]], np.int32)
    size = np.array([5], np.int32)
    d, ww, tt, cnt, live = R.defrag_rows_ref(
        *map(jnp.asarray, (dst, w, ts, size)), keep_all=True)
    assert cnt[0] == 5 and live[0] == 3      # pairs 1, 2, 3 all end live
    assert np.asarray(d)[0, :5].tolist() == [1, 1, 2, 3, 3]
    assert np.asarray(ww)[0, :5].tolist() == [0.0, 5.0, 1.0, 1.0, 2.0]


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31), st.integers(2, 16))
def test_append_kernel(seed, tile):
    """Fused append (slot scatter + pre-append last-writer probe) must match
    the oracle bit-exactly: pool contents AND per-pair was_live."""
    rng = np.random.default_rng(seed)
    NB, BS, B = 32, 8, 24
    dst = rng.integers(-1, 16, (NB, BS)).astype(np.int32)
    w = np.round(rng.uniform(0, 2, (NB, BS))).astype(np.float32)
    ts = (rng.permutation(NB * BS).reshape(NB, BS) + 1).astype(np.int32)
    wblk = rng.integers(0, NB, B).astype(np.int32)
    wlane = rng.integers(0, BS, B).astype(np.int32)
    wval = rng.random(B) < 0.7
    wd = rng.integers(0, 16, B).astype(np.int32)
    ww = np.round(rng.uniform(0, 2, B)).astype(np.float32)
    wts = (rng.permutation(B) + 1000).astype(np.int32)
    pstart = rng.integers(-1, NB, B).astype(np.int32)
    psize = rng.integers(0, 3 * BS, B).astype(np.int32)
    pv = rng.integers(-1, 16, B).astype(np.int32)
    args = tuple(map(jnp.asarray, (dst, w, ts, wblk, wlane, wval, wd, ww,
                                   wts, pstart, psize, pv)))
    a = R.append_ref(*args)
    b = append_pallas(*args, tile=tile)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def _touched_tiles(NB, BS, T, wblk, wval, pstart, psize):
    """Host replica of the edgepool touched-tile computation: probe
    extents marked as [first, last] tile ranges, landed slots as points."""
    n_tiles = NB // T
    touched = np.zeros(n_tiles, bool)
    for s, z in zip(pstart, psize):
        rows = -(-z // BS)
        if s >= 0 and rows > 0:
            touched[s // T:(s + rows - 1) // T + 1] = True
    for b, v in zip(wblk, wval):
        if v:
            touched[b // T] = True
    order = np.nonzero(touched)[0]
    n = len(order)
    tiles = np.full(n_tiles, order[-1] if n else 0, np.int32)
    tiles[:n] = order
    return tiles, n


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31))
def test_append_kernel_bounded_scan(seed):
    """The prefetched tile list must (a) reproduce the dense-probe oracle
    exactly — every tile a probe extent or landed slot can reach is
    visited — and (b) stay within the touched-extent bound: ops packed
    into a corner of the pool never visit the rest of it."""
    rng = np.random.default_rng(seed)
    NB, BS, B, T = 64, 8, 16, 8
    dst = rng.integers(-1, 16, (NB, BS)).astype(np.int32)
    w = np.round(rng.uniform(0, 2, (NB, BS))).astype(np.float32)
    ts = (rng.permutation(NB * BS).reshape(NB, BS) + 1).astype(np.int32)
    # ops confined to the first quarter of the pool: extents start in
    # rows [0, 8), slots land in rows [8, 16) — at/after the extent end,
    # the probe/write commutation invariant the production path upholds
    pstart = rng.integers(-1, 8, B).astype(np.int32)
    psize = rng.integers(0, BS + 1, B).astype(np.int32)
    pv = rng.integers(-1, 16, B).astype(np.int32)
    wblk = rng.integers(8, 16, B).astype(np.int32)
    wlane = rng.integers(0, BS, B).astype(np.int32)
    wval = rng.random(B) < 0.7
    wd = rng.integers(0, 16, B).astype(np.int32)
    ww = np.round(rng.uniform(0, 2, B)).astype(np.float32)
    wts = (rng.permutation(B) + 1000).astype(np.int32)

    tiles, n_touched = _touched_tiles(NB, BS, T, wblk, wval, pstart, psize)
    assert n_touched <= 2 * (16 // T)   # the touched-extent bound: 2 tiles
    args = tuple(map(jnp.asarray, (dst, w, ts, wblk, wlane, wval, wd, ww,
                                   wts, pstart, psize, pv)))
    a = R.append_ref(*args)
    b = append_pallas(*args, jnp.asarray(tiles),
                      jnp.asarray(n_touched, jnp.int32), tile=T)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31))
def test_frontier_kernel(seed):
    rng = np.random.default_rng(seed)
    NB, BS, n = 32, 8, 128
    W = n // 32
    owner = rng.integers(-1, n, NB).astype(np.int32)
    dst = rng.integers(-1, n, (NB, BS)).astype(np.int32)
    valid = rng.random((NB, BS)) < 0.5
    f = rng.integers(0, 2 ** 32, W, dtype=np.uint32)
    v = rng.integers(0, 2 ** 32, W, dtype=np.uint32)
    a = R.frontier_ref(*map(jnp.asarray, (owner, dst, valid, f, v)))
    b = frontier_pallas(*map(jnp.asarray, (owner, dst, valid, f, v)), tile=8)
    assert np.array_equal(np.asarray(a), np.asarray(b))
