"""Serving: engine greedy decode == full-forward greedy; RadixKV manager
invariants under random workloads (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.models.api import build_model
from repro.serve import RadixKVManager, ServeEngine


def _greedy_forward(cfg, params, prompt, steps):
    """Oracle: repeated full forward + argmax."""
    from repro.models import lm
    toks = jnp.asarray(prompt, jnp.int32)[None]
    out = []
    for _ in range(steps):
        pos = lm.make_positions(cfg, toks)
        h, _, _ = lm.forward(cfg, params, toks, pos, "train")
        nxt = int(jnp.argmax(lm._unembed(cfg, params, h)[0, -1]))
        out.append(nxt)
        toks = jnp.concatenate([toks, jnp.full((1, 1), nxt, jnp.int32)], 1)
    return out


def test_engine_matches_forward_greedy(rng):
    cfg = get_arch("internlm2-1.8b").SMOKE
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompts = [rng.integers(0, cfg.vocab, 7).astype(np.int32),
               rng.integers(0, cfg.vocab, 5).astype(np.int32)]
    eng = ServeEngine(m, params, slots=2, smax=64)
    results = eng.run(prompts, max_new=6)
    for i, p in enumerate(prompts):
        exp = _greedy_forward(cfg, params, p, 6)
        assert results[i] == exp, (i, results[i], exp)


admit_ops = st.lists(st.tuples(st.sampled_from(["admit", "append", "finish"]),
                               st.integers(1, 64)), min_size=1, max_size=200)


@settings(max_examples=30, deadline=None)
@given(admit_ops)
def test_radix_kv_invariants(ops):
    kv = RadixKVManager(total_blocks=64, block_tokens=4)
    live = {}
    for op, arg in ops:
        if op == "admit":
            sid = kv.admit(arg)
            if sid is not None:
                live[sid] = True
        elif op == "append" and live:
            sid = sorted(live)[arg % len(live)]
            kv.append_token(sid)
        elif op == "finish" and live:
            sid = sorted(live)[arg % len(live)]
            kv.finish(sid)
            del live[sid]
        # invariants: extents of live sequences never overlap, stay in pool
        spans = sorted((s.start_block, s.start_block + s.n_blocks)
                       for s in kv.seqs.values() if not s.finished)
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert a1 <= b0, "overlapping extents"
        if spans:
            assert spans[-1][1] <= kv.total_blocks
        # capacity discipline: cap covers tokens, bounded by ~4x live blocks
        for s in kv.seqs.values():
            if not s.finished:
                need = max(1, -(-s.tokens // kv.block_tokens))
                assert s.n_blocks >= need
                assert s.n_blocks <= 4 * need


def test_radix_kv_defrag_reclaims():
    kv = RadixKVManager(total_blocks=32, block_tokens=4)
    sids = [kv.admit(8) for _ in range(4)]         # 4 x 4 blocks = 16
    assert all(s is not None for s in sids)
    for s in sids[:3]:
        kv.finish(s)
    s2 = kv.admit(40)                              # needs 20 blocks -> defrag
    assert s2 is not None
    assert kv.defrags >= 1
    assert kv.overflow == 0
