"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step — output shapes + finiteness; prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_arch
from repro.models.api import build_model, input_specs, param_counts


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng):
    cfg = get_arch(arch).SMOKE
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.pos == "mrope":
        p = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.stack([p, p, p])
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                      jnp.float32)
    loss, grads = jax.value_and_grad(m.train_loss)(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-1.3b",
                                  "recurrentgemma-9b"])
def test_prefill_decode_matches_forward(arch, rng):
    """Greedy decode continuation == argmax of a full forward pass."""
    cfg = get_arch(arch).SMOKE
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    # reference: full forward logits at the last position
    from repro.models import lm
    pos = lm.make_positions(cfg, toks)
    h, _, _ = lm.forward(cfg, params, toks, pos, "train")
    ref_logits = lm._unembed(cfg, params, h)

    cache = m.init_cache(B, 64)
    pl, cache = m.prefill(params, {"tokens": toks}, cache)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(ref_logits[:, -1]),
                               rtol=2e-2, atol=2e-3)

    # decode the next token and compare with forward over S+1
    nxt = jnp.argmax(pl, -1).astype(jnp.int32)
    dl, cache = m.decode(params, {"token": nxt,
                                  "pos": jnp.full((B,), S, jnp.int32)}, cache)
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    pos2 = lm.make_positions(cfg, toks2)
    h2, _, _ = lm.forward(cfg, params, toks2, pos2, "train")
    ref2 = lm._unembed(cfg, params, h2)[:, -1]
    np.testing.assert_allclose(np.asarray(dl), np.asarray(ref2),
                               rtol=2e-2, atol=2e-3)


def test_param_counts_match_published():
    expect = {"mamba2-1.3b": 1.34, "qwen2.5-3b": 3.09, "phi3-mini-3.8b": 3.82,
              "deepseek-coder-33b": 33.3, "kimi-k2-1t-a32b": 1041.0}
    for arch, bn in expect.items():
        tot, _ = param_counts(get_arch(arch).CONFIG)
        assert tot / 1e9 == pytest.approx(bn, rel=0.02), arch
    _, act = param_counts(get_arch("kimi-k2-1t-a32b").CONFIG)
    assert act / 1e9 == pytest.approx(31.0, rel=0.05)


def test_input_specs_cover_cells():
    for arch in ARCH_IDS:
        mod = get_arch(arch)
        for shape, (kind, seq, batch) in SHAPES.items():
            if shape in getattr(mod, "SKIPS", {}):
                continue
            specs = input_specs(mod.CONFIG, kind, seq, batch)
            assert "tokens" in specs or "token" in specs
