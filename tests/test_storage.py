"""Durability subsystem: WAL codec + tolerant reader properties,
checkpoint (full and incremental) restore bit-exactness, crash-recovery
parity under injected faults, the typed refusal/recovery vocabulary, and
the service-level durable-ack / op-admission wiring.

The recovery contract under test everywhere: after ANY injected failure
(torn WAL tail, flipped bytes, torn checkpoint directories), ``recover``
reproduces EXACTLY the state of an uninterrupted control store applied
the same durable prefix — same epoch CSR snapshot, same ``num_edges``,
same analytics — and never raises on the damaged files.
"""
import os
import tempfile

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (AnalyticsOp, OpBatch, ReadOp, UnsupportedOpError,
                       make_store)
from repro.core.status import (ADVANCE_FALLBACKS, DELTA_REFUSALS, WAL_TAILS,
                               Reason)
from repro.storage import (DurableStore, FaultInjector, InjectedCrash,
                           WalWriter, checkpoint_ids, read_wal, recover,
                           restore_graph_checkpoint, save_graph_checkpoint)
from repro.storage.checkpoint import _dir_of
from repro.storage.faultfs import corrupt_checkpoint_array, tear_checkpoint
from repro.storage.wal import _scan, encode_record

CAPS = dict(n_max=512, pool_blocks=1024, block_size=8, dmax=256, k_max=64,
            batch=128)


def _store():
    return make_store("local", key_bits=32, expected_n=64,
                      undirected=False, m_cap=2048, **CAPS)


def _batches(seed, n_batches=6, size=96, n_ids=48, deletes=True):
    rng = np.random.default_rng(seed)
    ids = rng.choice(2 ** 32, n_ids, replace=False).astype(np.uint64)
    out = []
    for _ in range(n_batches):
        w = rng.uniform(0.5, 2.0, size).astype(np.float32)
        if deletes:
            w[rng.random(size) < 0.1] = 0.0
        out.append(OpBatch.edges(rng.choice(ids, size),
                                 rng.choice(ids, size), w))
    return out


def _sig(store):
    snap = store.read(ReadOp("snapshot"))
    return (store.read(ReadOp("num_edges")),
            [np.asarray(x) for x in jax.tree.leaves(snap)],
            store.analytics(AnalyticsOp("pagerank", {"iters": 8})))


def _assert_same(a, b, where=""):
    assert a[0] == b[0], f"{where}: num_edges {a[0]} != {b[0]}"
    for i, (x, y) in enumerate(zip(a[1], b[1])):
        assert np.array_equal(x, y), f"{where}: snapshot leaf {i}"
    assert a[2] == b[2], f"{where}: pagerank"


# ---- WAL codec: round-trip + tolerant-reader properties ----

def _rand_batch(rng, kind):
    n = int(rng.integers(0, 20))
    if kind == "edges":
        return OpBatch.edges(
            rng.integers(0, 2 ** 63, n, dtype=np.uint64),
            rng.integers(0, 2 ** 63, n, dtype=np.uint64),
            rng.uniform(0, 2, n).astype(np.float32))
    ctor = OpBatch.add_vertices if kind == "add_vertices" else \
        OpBatch.delete_vertices
    return ctor(rng.integers(0, 2 ** 63, n, dtype=np.uint64))


def _batch_equal(a: OpBatch, b: OpBatch):
    if a.kind != b.kind or len(a) != len(b):
        return False
    if a.kind == "edges":
        return (np.array_equal(a.src, b.src) and
                np.array_equal(a.dst, b.dst) and
                np.array_equal(np.asarray(a.weight, np.float32),
                               np.asarray(b.weight, np.float32)))
    return np.array_equal(a.ids, b.ids)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10 ** 6),
       st.lists(st.sampled_from(["edges", "add_vertices",
                                 "delete_vertices"]),
                min_size=0, max_size=8))
def test_wal_roundtrip_and_every_truncation_point(seed, kinds):
    """Arbitrary OpBatch sequences round-trip the WAL codec exactly, and
    EVERY byte-truncation point of the file yields the longest valid
    record prefix with a typed tail — never an exception."""
    rng = np.random.default_rng(seed)
    batches = [_rand_batch(rng, k) for k in kinds]
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "wal_prop.log")
        with WalWriter(path, group_commit=3) as w:
            for i, b in enumerate(batches):
                w.append(i, b)
        with open(path, "rb") as f:
            data = f.read()

    scan = _scan(data)
    assert scan.tail is Reason.OK and len(scan.records) == len(batches)
    for i, (rec, b) in enumerate(zip(scan.records, batches)):
        assert rec.seq == i and _batch_equal(rec.batch, b)

    # record end offsets: preamble, then cumulative record sizes
    ends, off = [], 8
    for i, b in enumerate(batches):
        off += len(encode_record(i, b))
        ends.append(off)
    assert off == len(data)
    for cut in range(len(data) + 1):
        part = _scan(data[:cut])
        n_complete = sum(1 for e in ends if e <= cut)
        assert len(part.records) == n_complete, (cut, n_complete)
        assert part.tail is Reason.OK or part.tail in WAL_TAILS
        if cut == len(data):
            assert part.tail is Reason.OK
        for rec, b in zip(part.records, batches):
            assert _batch_equal(rec.batch, b)


def test_wal_corruption_stops_at_longest_valid_prefix(tmp_path):
    batches = _batches(3, n_batches=4)
    path = tmp_path / "wal.log"
    with WalWriter(path) as w:
        for i, b in enumerate(batches):
            w.append(i, b)
    data = bytearray(path.read_bytes())
    # flip one payload byte inside record 2 (skip its header+crc)
    off = 8 + sum(len(encode_record(i, b))
                  for i, b in enumerate(batches[:2])) + 25
    data[off] ^= 0xFF
    path.write_bytes(bytes(data))
    scan = read_wal(path)
    assert scan.tail is Reason.WAL_BAD_CRC
    assert [r.seq for r in scan.records] == [0, 1]
    for rec, b in zip(scan.records, batches):
        assert _batch_equal(rec.batch, b)


# ---- checkpoints: full + incremental restore bit-exactness ----

def test_full_checkpoint_restore_bit_exact(tmp_path):
    store = _store()
    for b in _batches(1):
        store.apply(b)
    man = save_graph_checkpoint(tmp_path, store, incremental=True)
    assert man["kind"] == "full" and man["why_full"] == "no-base"

    fresh = _store()
    restore_graph_checkpoint(tmp_path, fresh)
    _assert_same(_sig(store), _sig(fresh), "full restore")
    assert fresh.stats["ops_applied"] == store.stats["ops_applied"]


def test_incremental_checkpoint_restore_bit_exact(tmp_path):
    store = _store()
    head, tail = _batches(2, n_batches=8)[:4], _batches(2, n_batches=8)[4:]
    for b in head:
        store.apply(b)
    save_graph_checkpoint(tmp_path, store)
    for b in tail:
        store.apply(b)
    man = save_graph_checkpoint(tmp_path, store, max_delta_frac=0.9)
    assert man["kind"] == "delta", man["why_full"]
    assert man["delta"]["n_blocks"] > 0

    fresh = _store()
    restore_graph_checkpoint(tmp_path, fresh)
    _assert_same(_sig(store), _sig(fresh), "delta restore")


def test_checkpoint_rejects_corrupt_members(tmp_path):
    store = _store()
    for b in _batches(4):
        store.apply(b)
    man = save_graph_checkpoint(tmp_path, store)
    corrupt_checkpoint_array(_dir_of(tmp_path, man["ckpt_id"]), "pool/dst")
    from repro.storage.checkpoint import CheckpointError, latest_recoverable
    assert latest_recoverable(tmp_path) is None
    with pytest.raises(CheckpointError) as ei:
        restore_graph_checkpoint(tmp_path, _store(), man["ckpt_id"])
    assert ei.value.code is Reason.CKPT_BAD_CRC


# ---- crash recovery: injected faults, bit-exact parity ----

def test_torn_wal_recovery_parity(tmp_path):
    """Crash mid-record (torn tail on disk): recovery truncates to the
    longest valid prefix and matches the control store bit for bit."""
    batches = _batches(5, n_batches=8)
    inj = FaultInjector(fail_after_records=5, torn_bytes=13)
    store = DurableStore(_store(), tmp_path, group_commit=1, injector=inj)
    with pytest.raises(InjectedCrash):
        for b in batches:
            store.apply(b)
    assert inj.crashed

    rec, report = recover(tmp_path, _store)
    assert report["wal_tail"] is Reason.WAL_TORN
    assert report["last_seq"] == 4    # 5 durable records: seqs 0..4
    ctrl = _store()
    for b in batches[:5]:
        ctrl.apply(b)
    _assert_same(_sig(ctrl), _sig(rec), "torn-WAL recovery")

    # the recovered store keeps ingesting; a fresh recovery still works
    # (the torn garbage must not shadow post-recovery appends)
    for b in batches[5:]:
        rec.apply(b)
        ctrl.apply(b)
    rec.sync()
    rec.close()
    rec2, report2 = recover(tmp_path, _store)
    assert report2["gap_at"] is None
    _assert_same(_sig(ctrl), _sig(rec2), "second recovery")


def test_group_commit_tail_loss_is_bounded(tmp_path):
    """With group_commit=k and no sync, a crash loses at most the
    unsynced tail — recovery lands on a batch boundary <= k behind."""
    batches = _batches(6, n_batches=7)
    store = DurableStore(_store(), tmp_path, group_commit=4)
    for b in batches:
        store.apply(b)
    # simulate kill -9: drop the handle without close/sync; the OS file
    # buffer (this process) holds the unsynced tail, so chop it like a
    # power cut would
    store.wal._f.flush()          # make buffered bytes visible to chop
    seg = store.wal.path
    synced = (len(batches) // 4) * 4
    keep = 8 + sum(len(encode_record(i, b))
                   for i, b in enumerate(batches[:synced]))
    with open(seg, "r+b") as f:
        f.truncate(keep)
    rec, report = recover(tmp_path, _store)
    assert report["last_seq"] == synced - 1
    ctrl = _store()
    for b in batches[:synced]:
        ctrl.apply(b)
    _assert_same(_sig(ctrl), _sig(rec), "group-commit tail loss")


def test_corrupt_checkpoint_falls_back_to_older_chain(tmp_path):
    """A flipped byte in the newest checkpoint: recovery falls back to
    the previous chain, replays the WAL suffix, truncates the dead
    checkpoint — and still matches the control exactly."""
    batches = _batches(7, n_batches=9)
    store = DurableStore(_store(), tmp_path, group_commit=1,
                         checkpoint_every=3)
    for b in batches:
        store.apply(b)      # checkpoints at batches 3, 6, 9
    store.close()
    ids = checkpoint_ids(tmp_path)
    assert len(ids) >= 2
    corrupt_checkpoint_array(_dir_of(tmp_path, ids[-1]), "pool/dst")

    rec, report = recover(tmp_path, _store)
    assert report["checkpoint"] == ids[-2]
    assert ids[-1] in report["truncated_ckpts"]
    ctrl = _store()
    for b in batches:
        ctrl.apply(b)
    _assert_same(_sig(ctrl), _sig(rec), "corrupt-ckpt fallback")


def test_torn_checkpoint_dir_falls_back(tmp_path):
    """A checkpoint directory missing its manifest (torn by non-atomic
    tooling) is invisible; recovery uses the older chain + WAL."""
    batches = _batches(8, n_batches=9)
    store = DurableStore(_store(), tmp_path, group_commit=1,
                         checkpoint_every=3)
    for b in batches:
        store.apply(b)
    store.close()
    ids = checkpoint_ids(tmp_path)
    tear_checkpoint(_dir_of(tmp_path, ids[-1]))          # manifest gone
    rec, report = recover(tmp_path, _store)
    assert report["checkpoint"] == ids[-2]
    ctrl = _store()
    for b in batches:
        ctrl.apply(b)
    _assert_same(_sig(ctrl), _sig(rec), "torn-ckpt-dir fallback")


def test_crash_at_group_commit_boundary(tmp_path):
    """``fail_on_sync``: everything appended is buffered but the fsync
    crashes — recovery still reads the flushed prefix (same process), and
    parity holds at whatever the report says survived."""
    batches = _batches(9, n_batches=5)
    inj = FaultInjector(fail_on_sync=True)
    store = DurableStore(_store(), tmp_path, group_commit=3, injector=inj)
    with pytest.raises(InjectedCrash):
        for b in batches:
            store.apply(b)
    store.wal._f.close()          # drop the handle, kill -9 style
    rec, report = recover(tmp_path, _store)
    survived = report["last_seq"] + 1
    assert 0 <= survived <= 3
    ctrl = _store()
    for b in batches[:survived]:
        ctrl.apply(b)
    _assert_same(_sig(ctrl), _sig(rec), "crash-at-sync recovery")


# ---- satellite 6: restore across a defrag boundary ----

def test_checkpoint_across_defrag_falls_back_to_full(tmp_path):
    """A defrag between checkpoints moves extents, so the delta's
    touched-row bookkeeping is void: the writer must fall back to a FULL
    checkpoint (``why_full == 'defrag'``), record the new defrag counter
    in the manifest, and restore bit-exactly."""
    store = _store()
    for b in _batches(10, n_batches=4):
        store.apply(b)
    man0 = save_graph_checkpoint(tmp_path, store)
    assert man0["kind"] == "full"

    store.graph.defrag()                    # rows recycled, extents move
    for b in _batches(11, n_batches=2):
        store.apply(b)
    man1 = save_graph_checkpoint(tmp_path, store, max_delta_frac=0.9)
    assert man1["kind"] == "full"
    assert man1["why_full"] == Reason.DEFRAG.value == "defrag"
    assert man1["defrags"] != man0["defrags"]

    fresh = _store()
    restore_graph_checkpoint(tmp_path, fresh)
    _assert_same(_sig(store), _sig(fresh), "post-defrag full restore")


def test_restore_invalidates_warm_analytics(tmp_path):
    """Warm incremental-analytics handles captured BEFORE a restore must
    not silently reuse stale row offsets afterwards: the advance refuses
    with ``Reason.RESTORE_BOUNDARY`` and answers exactly from scratch."""
    store = _store()
    rng = np.random.default_rng(12)
    ids = rng.choice(2 ** 32, 32, replace=False).astype(np.uint64)
    s, d = ids[rng.integers(0, 32, 80)], ids[rng.integers(0, 32, 80)]
    w = rng.uniform(1.0, 2.0, 80).astype(np.float32)
    store.apply(OpBatch.edges(np.concatenate([s, d]),
                              np.concatenate([d, s]),
                              np.concatenate([w, w])))
    op = AnalyticsOp("wcc", {})
    warm = store.analytics_result(op, store.capture())
    save_graph_checkpoint(tmp_path, store)

    # restore INTO THE SAME STORE (process adopted a checkpointed past);
    # physical row layout may now diverge from what `warm` remembers
    restore_graph_checkpoint(tmp_path, store)
    s2, d2 = ids[rng.integers(0, 32, 20)], ids[rng.integers(0, 32, 20)]
    w2 = rng.uniform(1.0, 2.0, 20).astype(np.float32)
    store.apply(OpBatch.edges(np.concatenate([s2, d2]),
                              np.concatenate([d2, s2]),
                              np.concatenate([w2, w2])))
    cur = store.capture()
    ri = store.analytics_advance(op, warm, cur)
    assert (ri.mode, ri.reason) == ("scratch", Reason.RESTORE_BOUNDARY)
    assert ri.value == store.analytics_result(op, cur).value

    # handles captured AFTER the restore advance incrementally again
    warm2 = store.analytics_result(op, cur)
    store.apply(OpBatch.edges(ids[:1], ids[1:2],
                              np.full(1, 1.5, np.float32)))
    ri2 = store.analytics_advance(op, warm2, store.capture())
    assert ri2.mode == "incremental", ri2.reason


# ---- satellite 1: the typed refusal vocabulary ----

def test_reason_vocabulary_distinct_and_string_compatible():
    vals = [r.value for r in ADVANCE_FALLBACKS]
    assert len(vals) == len(set(vals)), "fallback reasons must be distinct"
    assert DELTA_REFUSALS < ADVANCE_FALLBACKS
    # legacy string consumers keep working bit for bit
    assert Reason.DEFRAG == "defrag"
    assert str(Reason.VERTEX_EVENT) == "vertex-event"
    assert f"{Reason.ADVANCE_REFUSED}" == "advance-refused"
    assert "{}".format(Reason.WAL_TORN) == "wal-torn"
    import json
    assert json.loads(json.dumps({"r": Reason.DELTA_TOO_LARGE})) == \
        {"r": "delta-too-large"}
    # and every observed reason string parses back to a member
    for r in list(ADVANCE_FALLBACKS) + list(WAL_TAILS):
        assert Reason(r.value) is r


def test_every_advance_fallback_maps_to_distinct_member():
    """The ladder's possible refusals each hit a DISTINCT enum member —
    drive the main ones end-to-end and check the vocabulary covers all."""
    rng = np.random.default_rng(13)
    store = _store()
    ids = rng.choice(2 ** 32, 40, replace=False).astype(np.uint64)
    s, d = ids[rng.integers(0, 40, 120)], ids[rng.integers(0, 40, 120)]
    w = rng.uniform(1.0, 2.0, 120).astype(np.float32)
    store.apply(OpBatch.edges(np.concatenate([s, d]),
                              np.concatenate([d, s]),
                              np.concatenate([w, w])))
    # make a known-live pair so the tombstone below is an EFFECTIVE
    # delete in the delta, not a no-op on an absent edge
    store.apply(OpBatch.edges(ids[[0, 1]], ids[[1, 0]],
                              np.full(2, 0.8, np.float32)))
    seen = {}
    op = AnalyticsOp("bfs", dict(source=int(ids[0])))
    warm = store.analytics_result(op, store.capture())

    # deletes -> registry guard refusal
    store.apply(OpBatch.edges(ids[[0, 1]], ids[[1, 0]],
                              np.zeros(2, np.float32)))
    ri = store.analytics_advance(op, warm, store.capture())
    seen[ri.reason] = ri.mode
    warm = ri

    # vertex event
    store.apply(OpBatch.delete_vertices(ids[5:6]))
    ri = store.analytics_advance(op, warm, store.capture())
    seen[ri.reason] = ri.mode
    warm = ri

    # defrag (with a write after, so the epoch actually moves)
    store.graph.defrag()
    store.apply(OpBatch.edges(ids[:1], ids[3:4],
                              np.full(1, 0.7, np.float32)))
    ri = store.analytics_advance(op, warm, store.capture())
    seen[ri.reason] = ri.mode

    # fixed-iteration pagerank -> advance-refused (no warm program)
    pop = AnalyticsOp("pagerank", dict(iters=8))
    pwarm = store.analytics_result(pop, store.capture())
    store.apply(OpBatch.edges(ids[:1], ids[4:5],
                              np.full(1, 0.9, np.float32)))
    ri = store.analytics_advance(pop, pwarm, store.capture())
    seen[ri.reason] = ri.mode

    assert all(m == "scratch" for m in seen.values())
    observed = {Reason(r) for r in seen}
    assert len(observed) == len(seen), seen       # distinct members
    assert observed <= ADVANCE_FALLBACKS, seen


# ---- satellite 2: structured unsupported-op refusal ----

def test_sharded_vertex_batch_raises_structured_error():
    sh = make_store("sharded", n_shards=1, n_per_shard=512,
                    expected_n=128, pool_blocks=1024, block_size=8,
                    dmax=256, k_max=64, batch=128, query_batch=64)
    assert "add_vertices" not in sh.supported_ops
    with pytest.raises(UnsupportedOpError) as ei:
        sh.apply(OpBatch.add_vertices(np.arange(4, dtype=np.uint64)))
    assert ei.value.kind == "add_vertices"
    assert ei.value.backend == "sharded"
    assert isinstance(ei.value, NotImplementedError)   # legacy contract


def test_service_rejects_unsupported_vertex_ops():
    from repro.serve.graph_service import GraphQueryService
    sh = make_store("sharded", n_shards=1, n_per_shard=512,
                    expected_n=128, pool_blocks=1024, block_size=8,
                    dmax=256, k_max=64, batch=128, query_batch=64)
    svc = GraphQueryService(sh)
    assert svc.submit_add_vertices(np.arange(4, dtype=np.uint64)) is False
    assert svc.submit_delete_vertices(np.arange(2, dtype=np.uint64)) is False
    assert svc.stats["writes_rejected"] == 2
    svc.step()                      # nothing queued, nothing crashes

    local = _store()
    svc2 = GraphQueryService(local)
    assert svc2.submit_add_vertices(np.arange(4, dtype=np.uint64)) is True
    svc2.step()
    assert svc2.stats["vertex_ops"] == 1
    assert svc2.stats["writes_rejected"] == 0


# ---- service durable-ack mode ----

def test_service_durable_ack_syncs_before_reads(tmp_path):
    from repro.serve.graph_service import GraphQueryService
    store = DurableStore(_store(), tmp_path, group_commit=64)
    svc = GraphQueryService(store)
    assert svc.durable_ack
    rng = np.random.default_rng(14)
    ids = rng.choice(2 ** 32, 32, replace=False).astype(np.uint64)
    for _ in range(3):
        svc.submit_update(rng.choice(ids, 16), rng.choice(ids, 16),
                          rng.uniform(0.5, 2, 16).astype(np.float32))
        svc.step()
    assert svc.stats["durable_syncs"] == 3
    # group_commit=64 alone would have fsynced nothing yet: the service's
    # write-phase sync is what made these records durable
    assert store.stats["wal_syncs"] >= 3
    scan = read_wal(store.wal.path)
    assert scan.tail is Reason.OK and len(scan.records) == 3

    plain = GraphQueryService(_store())
    assert plain.durable_ack is False


# ---- the subprocess kill harness (CI smoke entry) ----

@pytest.mark.slow
def test_crash_smoke_subprocess():
    from repro.storage.crash_smoke import main
    assert main(["--seed", "1", "--ops", "2048", "--batch", "256",
                 "--group-commit", "4"]) == 0
