"""Analytics vs networkx oracles on a random graph with non-contiguous IDs."""
import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro import analytics as A
from repro.core.radixgraph import RadixGraph


@pytest.fixture(scope="module")
def graph():
    rng = np.random.default_rng(3)
    G = nx.gnm_random_graph(120, 420, seed=7)
    for (u, v) in G.edges:
        G[u][v]["weight"] = float(rng.uniform(0.5, 2.0))
    ids = np.array([u + 5000 for u in G.nodes], dtype=np.uint64)
    g = RadixGraph(n_max=512, key_bits=32, expected_n=128, batch=512,
                   pool_blocks=4096, block_size=8, dmax=1024,
                   undirected=True)
    g.add_vertices(ids)
    src = np.array([u + 5000 for u, v in G.edges], np.uint64)
    dst = np.array([v + 5000 for u, v in G.edges], np.uint64)
    w = np.array([G[u][v]["weight"] for u, v in G.edges], np.float32)
    g.add_edges(src, dst, w)
    snap = g.snapshot()
    off = g.lookup(ids)
    return G, g, snap, off, ids


def test_bfs(graph):
    G, g, snap, off, ids = graph
    nodes = list(G.nodes)
    depth = np.asarray(A.bfs(snap, jnp.int32(int(off[0]))))
    exp = nx.single_source_shortest_path_length(G, nodes[0])
    for i, nid in enumerate(nodes):
        assert depth[int(off[i])] == exp.get(nid, -1)


def test_sssp(graph):
    G, g, snap, off, ids = graph
    nodes = list(G.nodes)
    dist = np.asarray(A.sssp(snap, jnp.int32(int(off[0])), max_iters=128))
    exp = nx.single_source_dijkstra_path_length(G, nodes[0], weight="weight")
    for i, nid in enumerate(nodes):
        if nid in exp:
            assert dist[int(off[i])] == pytest.approx(exp[nid], abs=1e-3)
        else:
            assert dist[int(off[i])] > 1e37


def test_pagerank(graph):
    G, g, snap, off, ids = graph
    pr = np.asarray(A.pagerank(snap, iters=100))
    exp = nx.pagerank(G, alpha=0.85, max_iter=500, tol=1e-12, weight=None)
    for i, nid in enumerate(G.nodes):
        assert pr[int(off[i])] == pytest.approx(exp[nid], abs=1e-6)


def test_wcc(graph):
    G, g, snap, off, ids = graph
    lab = np.asarray(A.wcc(snap))
    nodes = list(G.nodes)
    for comp in nx.connected_components(G):
        labels = {lab[int(off[nodes.index(x)])] for x in comp}
        assert len(labels) == 1


def test_triangle_count(graph):
    G, g, snap, off, ids = graph
    assert int(A.triangle_count(snap)) == \
        sum(nx.triangles(G).values()) // 3


def test_bc(graph):
    G, g, snap, off, ids = graph
    bc = np.asarray(A.bc(snap, jnp.asarray(off, jnp.int32)))
    exp = nx.betweenness_centrality(G, normalized=False)
    for i, nid in enumerate(G.nodes):
        assert bc[int(off[i])] == pytest.approx(2 * exp[nid], abs=1e-2)


def test_khop(graph):
    G, g, snap, off, ids = graph
    nodes = list(G.nodes)
    kh = np.asarray(A.khop(snap, jnp.asarray(off[:8], jnp.int32), k=2))
    for i in range(8):
        exp = len(nx.single_source_shortest_path_length(
            G, nodes[i], cutoff=2)) - 1
        assert kh[i] == exp
