"""The unified GraphStore front door: typed IR validation, LocalStore
equivalence with the raw RadixGraph, epoch-handle reads, the analytics
registry, and (slow) the cross-backend parity suite — LocalStore and a
2-shard ShardedStore must return IDENTICAL results for the same
OpBatch/ReadOp/AnalyticsOp sequence, including WCC/SSSP/BC."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import (AnalyticsOp, LocalStore, OpBatch, ReadOp,
                       available_analytics, available_backends, make_store)


def _stream(seed=3, n_ids=80, n_ops=600):
    rng = np.random.default_rng(seed)
    ids = rng.choice(2 ** 32, n_ids, replace=False).astype(np.uint64)
    s0, d0 = rng.choice(ids, n_ops // 2), rng.choice(ids, n_ops // 2)
    src = np.concatenate([s0, d0])       # symmetric insertion (WCC-ready)
    dst = np.concatenate([d0, s0])
    wh = rng.uniform(0.5, 2, n_ops // 2).astype(np.float32)
    w = np.concatenate([wh, wh])
    w[rng.random(n_ops) < 0.1] = 0.0
    return ids, src, dst, w


def _local():
    return make_store("local", n_max=2048, key_bits=32, expected_n=256,
                      batch=512, pool_blocks=8192, block_size=8, dmax=512,
                      k_max=64)


# ---- IR validation ----

def test_ir_validation():
    with pytest.raises(ValueError):
        OpBatch(kind="nope", src=[1], dst=[2])
    with pytest.raises(ValueError):
        OpBatch.edges([1, 2], [3])                  # length mismatch
    with pytest.raises(ValueError):
        OpBatch(kind="add_vertices")                # ids missing
    with pytest.raises(ValueError):
        ReadOp("degree")                            # ids missing
    with pytest.raises(ValueError):
        ReadOp("frobnicate")
    b = OpBatch.edges([1, 2], [3, 4])
    assert len(b) == 2 and b.weight.dtype == np.float32
    k1 = AnalyticsOp("bfs", {"source": 5}).cache_key()
    k2 = AnalyticsOp("bfs", {"source": 5}).cache_key()
    k3 = AnalyticsOp("bfs", {"source": 6}).cache_key()
    assert k1 == k2 and k1 != k3
    ka = AnalyticsOp("bc", {"sources": np.array([1, 2])}).cache_key()
    kb = AnalyticsOp("bc", {"sources": np.array([1, 3])}).cache_key()
    assert ka != kb


def test_registry_and_backends():
    assert {"local", "sharded"} <= set(available_backends())
    # the full distributed-analytics registry (ROADMAP gap closed)
    assert {"bfs", "pagerank", "wcc", "sssp", "bc", "khop"} <= \
        set(available_analytics(distributed=True))
    assert "triangle_count" in available_analytics()
    with pytest.raises(KeyError):
        make_store("nope")
    with pytest.raises(KeyError):
        _local().analytics(AnalyticsOp("nope"))


# ---- LocalStore vs the raw RadixGraph ----

def test_local_store_matches_radixgraph():
    from repro import analytics as A
    import jax.numpy as jnp
    from repro.core.radixgraph import RadixGraph

    ids, src, dst, w = _stream()
    store = _local()
    res = store.apply(OpBatch.edges(src, dst, w))
    assert res.applied == len(src) and res.dropped == 0

    g = RadixGraph(n_max=2048, key_bits=32, expected_n=256, batch=512,
                   pool_blocks=8192, block_size=8, dmax=512, k_max=64)
    g.apply_ops(src, dst, w)
    assert store.read(ReadOp("num_edges")) == g.num_edges
    assert store.read(ReadOp("num_vertices")) == g.num_vertices
    off = g.lookup(ids)
    assert np.array_equal(store.read(ReadOp("lookup", ids=ids)), off >= 0)

    snap = g.snapshot(m_cap=store.m_cap)
    depth = store.analytics(AnalyticsOp("bfs", {"source": int(src[0]),
                                                "max_iters": 64}))
    s0 = int(g.lookup(np.array([src[0]], np.uint64))[0])
    ref = np.asarray(A.bfs(snap, jnp.int32(s0), max_iters=64))
    for i, vid in enumerate(ids):
        assert depth[int(vid)] == int(ref[int(off[i])])

    # degrees agree with per-id neighbor lists
    deg = store.read(ReadOp("degree", ids=ids[:16]))
    nbrs = store.read(ReadOp("neighbors", ids=ids[:16]))
    assert [len(a) for a, _ in nbrs] == deg.tolist()


def test_local_vertex_batches_and_absent_reads():
    store = _local()
    store.apply(OpBatch.add_vertices([7, 8, 9]))
    assert store.read(ReadOp("num_vertices")) == 3
    assert store.read(ReadOp("lookup", ids=[7, 8, 9, 10])).tolist() == \
        [True, True, True, False]
    store.apply(OpBatch.delete_vertices([8]))
    assert store.read(ReadOp("lookup", ids=[8]))[0] == np.False_
    # absent vertices: degree 0, empty neighbors, unreachable analytics
    assert store.read(ReadOp("degree", ids=[404]))[0] == 0
    assert len(store.read(ReadOp("neighbors", ids=[404]))[0][0]) == 0
    d = store.analytics(AnalyticsOp("bfs", {"source": 404}))
    assert all(v == -1 for v in d.values())
    k = store.analytics(AnalyticsOp("khop", {"sources": [7, 404], "k": 2}))
    assert k[1] == 0


def test_epoch_capture_reads():
    ids, src, dst, w = _stream(seed=11)
    store = _local()
    store.apply(OpBatch.edges(src[:300], dst[:300], w[:300]))
    h = store.capture()
    ne0 = store.read(ReadOp("num_edges"))
    deg0 = store.read(ReadOp("degree", ids=ids[:8]))
    store.apply(OpBatch.edges(src[300:], dst[300:], w[300:]))
    # the captured epoch still answers the pre-write state
    assert store.read(ReadOp("num_edges"), at=h) == ne0
    assert np.array_equal(store.read(ReadOp("degree", ids=ids[:8]), at=h),
                          deg0)
    assert store.clock(at=h) <= store.clock()
    pr_old = store.analytics(AnalyticsOp("pagerank", {"iters": 5}), at=h)
    pr_new = store.analytics(AnalyticsOp("pagerank", {"iters": 5}))
    assert set(pr_old) <= set(pr_new)


def test_service_runs_on_local_backend():
    """The query service is storage-agnostic: a LocalStore serves the same
    mixed workload the sharded engine does."""
    from repro.serve.graph_service import GraphQueryService

    ids, src, dst, w = _stream(seed=5)
    svc = GraphQueryService(_local(), query_batch=64)
    svc.submit_update(src, dst, w)
    svc.run()                                 # drain + seal the epoch
    t = svc.submit_query("degree", ids=ids[:16])
    tw = svc.submit_query("wcc")
    svc.run()
    ref = _local()
    ref.apply(OpBatch.edges(src, dst, w))
    assert np.array_equal(svc.claim(t),
                          ref.read(ReadOp("degree", ids=ids[:16])))
    assert svc.claim(tw) == ref.analytics(AnalyticsOp("wcc"))


# ---- pipelined K-batch apply (PR 6) ----

def test_pipelined_apply_bitexact_with_defrag_and_ragged_tail():
    """A K-deep pipelined apply (scanned super-batches, donated steady-state
    buffers) is BIT-EXACT vs the K=1 sequential reference — including an
    overflow defrag firing mid-super-batch (tiny probe window + k_big=1 on
    a hub stream) and a ragged final super-batch K' < K (5 batches at
    K=2 -> groups [2, 2, 1])."""
    import jax

    def mk(depth, donate):
        # fuse_scan exercises the single-program lax.scan entry (the
        # default steady state dispatches flat donated programs instead)
        return make_store("local", n_max=2048, key_bits=32, expected_n=256,
                          batch=512, pool_blocks=8192, block_size=8,
                          dmax=512, k_max=64, probe_width=8, k_big=1,
                          pipeline_depth=depth, donate_apply=donate,
                          fuse_scan=depth > 1)

    rng = np.random.default_rng(7)
    ids = rng.choice(2 ** 32, 96, replace=False).astype(np.uint64)
    hubs = ids[:6]                       # 6 hubs > k_big=1: defrag fallback
    n_ops = 512 * 5                      # NB=5 batches
    src = hubs[np.arange(n_ops) % len(hubs)]
    dst = ids[rng.integers(0, len(ids), n_ops)]
    w = rng.uniform(0.5, 2, n_ops).astype(np.float32)
    w[rng.random(n_ops) < 0.1] = 0.0

    ref = mk(1, False)
    pipe = mk(2, True)
    r1 = ref.apply(OpBatch.edges(src, dst, w))
    r2 = pipe.apply(OpBatch.edges(src, dst, w))
    assert r1.dropped == r2.dropped
    for a, b in zip(jax.tree.leaves(ref.graph.state),
                    jax.tree.leaves(pipe.graph.state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # the stream must actually have exercised the mid-scan defrag fallback
    assert pipe.graph.num_defrags >= 1
    assert pipe.graph.num_defrags == ref.graph.num_defrags
    assert ref.read(ReadOp("num_edges")) == pipe.read(ReadOp("num_edges"))
    # flush accounting: one apply = one flush; 5 batches at K=2 ship as
    # [2, 2, 1] — the ragged tail is its own dispatch, never clock-padded
    assert pipe.stats["flushes"] == 1 and pipe.stats["super_batches"] == 3
    assert ref.stats["super_batches"] == 5


def test_pipelined_apply_donation_epoch_safety():
    """Captured epochs stay readable across donating steady-state applies:
    capture() pins the live state (first dispatch after a pin runs the
    non-donating program), so MVCC handles never observe freed buffers."""
    ids, src, dst, w = _stream(seed=13)
    store = make_store("local", n_max=2048, key_bits=32, expected_n=256,
                       batch=512, pool_blocks=8192, block_size=8, dmax=512,
                       k_max=64, pipeline_depth=4)
    store.apply(OpBatch.edges(src[:300], dst[:300], w[:300]))
    h = store.capture()
    ne0 = store.read(ReadOp("num_edges"), at=h)
    deg0 = store.read(ReadOp("degree", ids=ids[:8]), at=h)
    for _ in range(3):                  # steady state: donating dispatches
        store.apply(OpBatch.edges(src[300:], dst[300:], w[300:]))
    assert store.read(ReadOp("num_edges"), at=h) == ne0
    assert np.array_equal(store.read(ReadOp("degree", ids=ids[:8]), at=h),
                          deg0)


def test_apply_donation_memory_analysis():
    """HLO memory analysis of the K-batch apply program: the donated
    variant aliases the state bytes into the output (no second pool image),
    so its peak live bytes drop vs the non-donating program."""
    import jax
    import jax.numpy as jnp
    from repro.core import radixgraph as rgm

    g = _local().graph
    st = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                      g.state)
    B, K = g.batch, 4
    args = (jax.ShapeDtypeStruct((K, B, 2), jnp.uint32),
            jax.ShapeDtypeStruct((K, B, 2), jnp.uint32),
            jax.ShapeDtypeStruct((K, B), jnp.float32),
            jax.ShapeDtypeStruct((K, B), bool))
    plain = rgm._update_edges_pipe.lower(
        g.sort_spec, g.pool_spec, st, *args).compile().memory_analysis()
    don = rgm._update_edges_pipe_donate.lower(
        g.sort_spec, g.pool_spec, st, *args).compile().memory_analysis()

    def peak(m):
        return (m.argument_size_in_bytes + m.output_size_in_bytes +
                m.temp_size_in_bytes - m.alias_size_in_bytes)

    state_bytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                      for x in jax.tree.leaves(st))
    assert plain.alias_size_in_bytes == 0
    # the donated program reuses (nearly) the whole state image in place —
    # at least the pool's dst/weight/ts arrays must alias
    assert don.alias_size_in_bytes >= state_bytes // 2
    assert peak(don) <= peak(plain) - state_bytes // 2


# ---- cross-backend parity (subprocess: needs 2 devices) ----

@pytest.mark.slow
def test_cross_backend_parity_subprocess():
    """LocalStore and a 2-shard ShardedStore must answer the SAME
    OpBatch/ReadOp/AnalyticsOp sequence identically: lookups, degrees,
    neighbors, counts, BFS, PageRank, WCC, SSSP, BC and k-hop (the new
    registry entries asserted bit-exact / <1e-5 for float-sum BC)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import numpy as np
        from repro.api import AnalyticsOp, OpBatch, ReadOp, make_store

        rng = np.random.default_rng(3)
        ids = rng.choice(2**32, 80, replace=False).astype(np.uint64)
        B = 600
        s0, d0 = rng.choice(ids, B // 2), rng.choice(ids, B // 2)
        src = np.concatenate([s0, d0]); dst = np.concatenate([d0, s0])
        wh = rng.uniform(0.5, 2, B // 2).astype(np.float32)
        w = np.concatenate([wh, wh])
        w[rng.random(B) < 0.1] = 0.0

        stores = {
            "local": make_store("local", n_max=2048, key_bits=32,
                                expected_n=256, batch=512, pool_blocks=8192,
                                block_size=8, dmax=512, k_max=64),
            "sharded": make_store("sharded", n_shards=2, n_per_shard=2048,
                                  expected_n=256, pool_blocks=8192,
                                  block_size=8, dmax=512, k_max=64,
                                  batch=512, query_batch=64),
        }
        results = {}
        for name, st in stores.items():
            assert st.apply(OpBatch.edges(src, dst, w)).dropped == 0
            res = {}
            res["lookup"] = st.read(ReadOp("lookup", ids=ids)).tolist()
            res["degree"] = st.read(ReadOp("degree", ids=ids)).tolist()
            res["nv"] = st.read(ReadOp("num_vertices"))
            res["ne"] = st.read(ReadOp("num_edges"))
            res["neighbors"] = [sorted(zip(a.tolist(), b.tolist()))
                                for a, b in st.read(
                                    ReadOp("neighbors", ids=ids[:10]))]
            res["bfs"] = st.analytics(AnalyticsOp(
                "bfs", {"source": int(src[0]), "max_iters": 64}))
            res["pr"] = st.analytics(AnalyticsOp("pagerank", {"iters": 15}))
            res["wcc"] = st.analytics(AnalyticsOp("wcc"))
            res["sssp"] = st.analytics(AnalyticsOp(
                "sssp", {"source": int(src[0]), "max_iters": 64}))
            res["bc"] = st.analytics(AnalyticsOp(
                "bc", {"sources": ids[:8], "max_depth": 16}))
            for k in (1, 2, 3):
                res[f"khop{k}"] = st.analytics(AnalyticsOp(
                    "khop", {"sources": ids[:16], "k": k})).tolist()
            res["bfs_ghost"] = st.analytics(AnalyticsOp(
                "bfs", {"source": 123456789}))
            res["deg_ghost"] = st.read(
                ReadOp("degree", ids=np.array([123456789],
                                              np.uint64))).tolist()
            results[name] = res
        a, b = results["local"], results["sharded"]
        assert set(a) == set(b)
        for k in a:
            if k in ("pr", "bc"):     # float-sum accumulation order
                assert set(a[k]) == set(b[k]), k
                err = max(abs(a[k][x] - b[k][x]) / max(1.0, abs(a[k][x]))
                          for x in a[k])
                assert err < 1e-5, (k, err)
            else:
                assert a[k] == b[k], (k, a[k], b[k])
        print("PARITY-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                          "PYTHONPATH": "src"},
                         cwd=str(__import__("pathlib").Path(
                             __file__).resolve().parents[1]), timeout=600)
    assert "PARITY-OK" in out.stdout, out.stderr[-2000:]
