"""SORT configuration optimizer (paper §3.2): DP == brute force, paper
configs reproduced, Lemma 1, baseline dominance."""
import itertools
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sort_optimizer import (expected_space, node_probability,
                                       optimize_sort, uniform_config,
                                       veb_config)


def brute_force(n, x, l):
    """Optimal over trees with AT MOST l layers, all fanouts >= 1 (zero
    layers are pruned per paper §3.2)."""
    best = None
    for ll in range(1, l + 1):
        for a in itertools.product(range(1, x + 1), repeat=ll):
            if sum(a) < x:
                continue
            v = expected_space(list(a), x, n)
            if best is None or v < best - 1e-9:
                best = v
    return best


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 200), st.integers(4, 10), st.integers(2, 3))
def test_dp_matches_brute_force(n, x, l):
    c = optimize_sort(n, x, l)
    assert c.expected_space == pytest.approx(brute_force(n, x, l), rel=1e-6)


def test_paper_fig12a_configs():
    # the published optimal fanouts for u = 2^32, l = 5
    assert optimize_sort(50_000, 32, 5).fanout_bits == (19, 4, 3, 3, 3)
    assert optimize_sort(300_000, 32, 5).fanout_bits == (20, 3, 3, 3, 3)


def test_lemma1_total_bits_exactly_x():
    for n in (10, 1000, 10 ** 6):
        for x in (16, 32, 48):
            c = optimize_sort(n, x, 5)
            assert sum(c.fanout_bits) == x


def test_sort_dominates_baselines():
    for n in (1000, 10 ** 5):
        s = optimize_sort(n, 32, 5).expected_space
        assert s <= uniform_config(n, 32, 5).expected_space + 1e-6
        assert s <= veb_config(n, 32).expected_space + 1e-6


def test_node_probability_sane():
    assert node_probability(32, 32, 5) == 1.0       # whole-universe node
    assert node_probability(32, 0, 1) == pytest.approx(2 ** -32, rel=1e-3)
    p_small = node_probability(32, 8, 100)
    p_big = node_probability(32, 16, 100)
    assert 0 < p_small < p_big < 1


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 10 ** 6), st.sampled_from([16, 32, 64]))
def test_monotone_space_in_n(n, x):
    a = optimize_sort(n, x, 5).expected_space
    b = optimize_sort(min(2 * n, 2 ** x - 1), x, 5).expected_space
    assert b >= a * 0.999
