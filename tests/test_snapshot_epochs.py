"""Versioned read path: epoch-cached snapshots, the incremental live-edge
counter, MVCC reads across defrag, and retained-version lifecycle."""
import numpy as np
import pytest

from repro.core.radixgraph import RadixGraph


def mk(**kw):
    args = dict(n_max=256, key_bits=16, expected_n=64, batch=128,
                pool_blocks=4096, block_size=8, dmax=512, k_max=32)
    args.update(kw)
    return RadixGraph(**args)


def _wave(g, rng, n=200, ids=30, del_frac=0.2):
    src = rng.integers(0, ids, n).astype(np.uint64)
    dst = rng.integers(0, ids, n).astype(np.uint64)
    w = rng.uniform(0.5, 2, n).astype(np.float32)
    w[rng.random(n) < del_frac] = 0.0
    g.apply_ops(src, dst, w)
    return src, dst, w


def test_snapshot_cache_hit_no_rescan(rng):
    g = mk()
    _wave(g, rng)
    # num_edges reads the incremental counter: no CSR build at all
    m = g.num_edges
    assert g.snapshot_misses == 0 and g.snapshot_hits == 0
    s1 = g.snapshot()
    assert g.snapshot_misses == 1
    s2 = g.snapshot()
    assert s2 is s1, "unchanged graph must return the cached artifact"
    assert (g.snapshot_hits, g.snapshot_misses) == (1, 1)
    assert int(s1.m) == m
    # repeated counter reads never build anything either
    assert g.num_edges == m and g.snapshot_misses == 1


def test_snapshot_cache_invalidated_by_every_mutation(rng):
    g = mk()
    _wave(g, rng)
    mutations = [
        lambda: g.add_vertices([200]),
        lambda: g.add_edges(np.array([1], np.uint64),
                            np.array([2], np.uint64)),
        lambda: g.update_edges(np.array([1], np.uint64),
                               np.array([2], np.uint64), [3.0]),
        lambda: g.delete_edges(np.array([1], np.uint64),
                               np.array([2], np.uint64)),
        lambda: g.apply_ops(np.array([3], np.uint64),
                            np.array([4], np.uint64), [1.5]),
        lambda: g.delete_vertices([4]),
        lambda: g.defrag(),
    ]
    for mutate in mutations:
        before = g.snapshot()
        misses = g.snapshot_misses
        mutate()
        after = g.snapshot()
        assert after is not before, mutate
        assert g.snapshot_misses == misses + 1, mutate


def test_live_edge_counter_matches_rebuild_under_churn(rng):
    g = mk()
    oracle = {}
    for _ in range(5):
        src, dst, w = _wave(g, rng)
        for s, d, ww in zip(src, dst, w):
            if ww == 0:
                oracle.pop((int(s), int(d)), None)
            else:
                oracle[(int(s), int(d))] = float(ww)
        assert int(g.state.pool.live_dirty) == 0
        assert g.num_edges == len(oracle)           # counter path
        assert g.num_edges == int(g.snapshot().m)   # vs full rebuild
    assert not g.overflowed


def test_vertex_delete_dirties_then_recounts(rng):
    g = mk()
    g.apply_ops(np.array([1, 2, 3], np.uint64), np.array([2, 3, 1], np.uint64),
                np.array([1, 1, 1], np.float32))
    assert g.num_edges == 3
    g.delete_vertices([2])
    assert int(g.state.pool.live_dirty) == 1
    assert g.num_edges == 1                         # recount via snapshot
    assert int(g.state.pool.live_dirty) == 0        # written back
    assert g.num_edges == 1                         # counter path again
    # defrag is also a resynchronization point
    g.delete_vertices([3])
    g.defrag()
    assert int(g.state.pool.live_dirty) == 0
    assert g.num_edges == 0


def test_counter_dirty_when_degree_exceeds_probe_window(rng):
    """A vertex whose edge array outgrows the dmax probe window must flag
    the counter dirty (the newest entry of a probed pair may sit past the
    window) instead of silently drifting."""
    g = mk(dmax=8, block_size=8, k_max=8)
    src = np.zeros(16, np.uint64)
    dst = np.arange(1, 17, dtype=np.uint64)
    g.apply_ops(src, dst, np.ones(16, np.float32))
    assert g.num_edges == 16
    # update an existing pair: probe window (8) < degree (16)
    g.apply_ops(np.zeros(1, np.uint64), np.array([16], np.uint64),
                np.array([2.0], np.float32))
    assert g.num_edges == 16        # recount, not 17
    g.apply_ops(np.zeros(1, np.uint64), np.array([15], np.uint64),
                np.array([0.0], np.float32))
    assert g.num_edges == 15        # delete seen despite blind probe


def test_mvcc_versioned_snapshot_across_defrag(rng):
    """A versioned read taken BEFORE a defrag must still answer correctly
    from the retained state: the defrag drops superseded versions from the
    live arrays, so ``snapshot_at`` resolves against the checkpoint."""
    g = mk()
    g.apply_ops(np.array([1, 1, 2], np.uint64), np.array([2, 3, 3], np.uint64),
                np.array([1.0, 2.0, 4.0], np.float32))
    ts1 = g.checkpoint_version()
    hist = {(1, 2): 1.0, (1, 3): 2.0, (2, 3): 4.0}
    # overwrite (1,2), delete (1,3), add (3,1); then defrag away old versions
    g.apply_ops(np.array([1, 1, 3], np.uint64), np.array([2, 3, 1], np.uint64),
                np.array([9.0, 0.0, 1.0], np.float32))
    g.defrag()
    snap = g.snapshot_at(ts1)
    assert int(snap.m) == len(hist)
    off = {int(v): int(o) for v, o in zip([1, 2, 3], g.lookup([1, 2, 3]))}
    dst = np.asarray(snap.dst)
    wgt = np.asarray(snap.weight)
    indptr = np.asarray(snap.indptr)
    got = {}
    for vid, o in off.items():
        for e in range(indptr[o], indptr[o + 1]):
            did = [k for k, v in off.items() if v == dst[e]][0]
            got[(vid, did)] = float(wgt[e])
    assert got == hist
    # the live state answers the CURRENT view
    assert g.num_edges == 3  # (1,2)=9, (2,3)=4, (3,1)=1


def test_versioned_neighbor_reads_across_defrag(rng):
    g = mk()
    g.apply_ops(np.array([1, 1], np.uint64), np.array([2, 3], np.uint64),
                np.array([1.0, 1.0], np.float32))
    ts1 = g.checkpoint_version()
    g.apply_ops(np.array([1, 1], np.uint64), np.array([2, 4], np.uint64),
                np.array([0.0, 5.0], np.float32))
    g.defrag()   # live arrays lose the (1,2) tombstone AND its old version
    lbl, vts, state = g._versions[0][0], g._versions[0][1], g._versions[0][2]
    assert vts == ts1
    old = RadixGraph.__new__(RadixGraph)
    old.__dict__.update(g.__dict__)
    old.state = state
    ids, w = old.neighbors([1], read_ts=ts1)[0]
    assert set(ids.tolist()) == {2, 3}
    # current view after defrag unaffected
    ids, w = g.neighbors([1])[0]
    assert set(ids.tolist()) == {3, 4}


def test_release_version_prunes_retained_states(rng):
    g = mk()
    _wave(g, rng, n=50)
    t1 = g.checkpoint_version(label=101)
    _wave(g, rng, n=50)
    t2 = g.checkpoint_version(label=102)
    assert [lbl for lbl, _ in g.retained_versions] == [101, 102]
    assert g.release_version(101) == 1
    assert [lbl for lbl, _ in g.retained_versions] == [102]
    # releasing an unknown label is a no-op
    assert g.release_version(999) == 0
    # snapshot_at still resolves via the remaining (later) version
    snap = g.snapshot_at(t1)
    assert int(snap.m) >= 0
    assert g.release_version(102) == 1
    assert g.retained_versions == []


def test_snapshot_at_falls_back_to_live_state(rng):
    g = mk()
    _wave(g, rng, n=80, del_frac=0.0)
    # no retained versions: historical read served from the live state
    ts = g.current_ts
    snap = g.snapshot_at(ts)
    assert int(snap.m) == g.num_edges
