"""Distribution: sharding planner resolution, gradient compression
properties, multi-shard graph engine (subprocess: needs >1 device)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dep (pyproject test extras) — never hard-fail collection
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAS_HYPOTHESIS = False

from repro.dist.compress import dequantize_int8, quantize_int8
from repro.dist.sharding import (SERVE_RULES, TRAIN_RULES, spec_for)
from jax.sharding import PartitionSpec as P


class FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


def test_spec_divisibility_fallback():
    mesh = FakeMesh()
    # 12 heads % 16 != 0 -> replicated; 8960 ffn % 16 == 0 -> sharded
    assert spec_for((28, 1536, 12 * 128), ("layers", "fsdp", "tp"),
                    TRAIN_RULES, mesh) == P(None, ("pod", "data"), "model")
    assert spec_for((12,), ("heads",), TRAIN_RULES, mesh) == P(None)
    # one mesh axis never used twice within a tensor
    s = spec_for((256, 256), ("tp", "tp_in"), TRAIN_RULES, mesh)
    used = [a for a in s if a is not None]
    assert len(used) == len(set(used)) <= 1


def test_spec_batch_axes_compose():
    mesh = FakeMesh()
    assert spec_for((256, 4096), ("batch", None), TRAIN_RULES, mesh) == \
        P(("pod", "data"), None)
    # batch=1 (long_500k): indivisible -> replicated
    assert spec_for((1, 524288), ("batch", None), SERVE_RULES, mesh) == \
        P(None, None)


def _check_int8_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 3, (64,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6   # half-ULP rounding


if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2 ** 31))
    def test_int8_quantization_bounded_error(seed):
        _check_int8_bounded_error(seed)
else:  # fixed-seed fallback keeps the property exercised without hypothesis
    @pytest.mark.parametrize("seed", [0, 1, 7, 123456789, 2 ** 31])
    def test_int8_quantization_bounded_error(seed):
        _check_int8_bounded_error(seed)


def test_error_feedback_unbiased_accumulation():
    """With error feedback, the accumulated compressed signal tracks the
    accumulated true signal (residual stays bounded)."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros((32,))
    acc_true = np.zeros((32,))
    acc_comp = np.zeros((32,))
    for t in range(200):
        g = jnp.asarray(rng.normal(0, 1, (32,)).astype(np.float32))
        corrected = g + residual
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        residual = corrected - deq
        acc_true += np.asarray(g)
        acc_comp += np.asarray(deq)
    # the residual bounds the total divergence (telescoping sum)
    assert np.abs(acc_true - acc_comp).max() == pytest.approx(
        np.abs(np.asarray(residual)).max(), abs=1e-4)
    assert np.abs(np.asarray(residual)).max() < 0.2


@pytest.mark.slow
def test_graph_engine_multishard_subprocess():
    """Vertex-space sharding over 4 placeholder devices: routed edge ops +
    owner-answered degree queries match a host oracle."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.core.sort import SortSpec
        from repro.core.sort_optimizer import optimize_sort
        from repro.core import edgepool as ep
        from repro.core.keys import pack_keys
        from repro.dist.graph_engine import (make_sharded_state,
                                             make_apply_edges,
                                             make_khop_counts)
        mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
        cfg = optimize_sort(256, 32, 5)
        sspec = SortSpec.from_config(cfg, 1024)
        pspec = ep.PoolSpec(n_blocks=1024, block_size=8, k_max=32, dmax=256)
        state = make_sharded_state(sspec, pspec, 4, 1024)
        apply_fn = jax.jit(make_apply_edges(sspec, pspec, mesh, "data"))
        khop = jax.jit(make_khop_counts(sspec, pspec, mesh, "data"))
        rng = np.random.default_rng(0)
        ids = rng.choice(2**32, 100, replace=False).astype(np.uint64)
        B = 1024
        src = rng.choice(ids, B); dst = rng.choice(ids, B)
        w = rng.uniform(0.5, 2, B).astype(np.float32)
        state, dropped = apply_fn(state, pack_keys(src, 32),
                                  pack_keys(dst, 32), jnp.asarray(w),
                                  jnp.ones(B, bool))
        assert int(np.asarray(dropped).sum()) == 0
        deg = {}
        for (s, d) in {(int(a), int(b)) for a, b in zip(src, dst)}:
            deg[s] = deg.get(s, 0) + 1
        q = ids[:32]
        got = np.asarray(khop(state, pack_keys(q, 32)))
        exp = np.array([deg.get(int(x), 0) for x in q])
        assert np.array_equal(got, exp), (got, exp)
        print("MULTISHARD-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                          "PYTHONPATH": "src"},
                         cwd=str(__import__("pathlib").Path(
                             __file__).resolve().parents[1]), timeout=600)
    assert "MULTISHARD-OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_compacted_exchange_subprocess():
    """Frontier-compacted exchange over 2 placeholder devices must be
    BIT-EXACT against the dense exchange for BFS, PageRank and k-hop
    (k <= 3) on both a sparse frontier (path graph, compact route taken)
    and a full frontier (tiny budget forces the dense fallback round), and
    the incremental + budgeted vertex sync must equal the full sync."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.core.sort import SortSpec
        from repro.core.sort_optimizer import optimize_sort
        from repro.core import edgepool as ep
        from repro.core.keys import pack_keys
        from repro.core.radixgraph import RadixGraph
        from repro import analytics as A
        from repro.dist.graph_engine import (make_sharded_state,
            make_apply_edges, make_sync_vertices, make_bfs, make_pagerank,
            make_khop_counts)
        mesh = jax.make_mesh((2,), ("data",), axis_types=(AxisType.Auto,))
        cfg = optimize_sort(256, 32, 5)
        sspec = SortSpec.from_config(cfg, 1024)
        pspec = ep.PoolSpec(n_blocks=1024, block_size=8, k_max=32, dmax=256)
        rng = np.random.default_rng(5)
        ids = rng.choice(2**32, 100, replace=False).astype(np.uint64)
        m_cap = 4096
        def ingest(src, dst, w, route_budget=None):
            st = make_sharded_state(sspec, pspec, 2, 1024)
            ap = jax.jit(make_apply_edges(sspec, pspec, mesh, "data",
                                          route_budget=route_budget))
            B = len(src)
            st, dr = ap(st, pack_keys(src, 32), pack_keys(dst, 32),
                        jnp.asarray(w), jnp.ones(B, bool))
            assert int(np.asarray(dr).sum()) == 0
            return st
        def check(src, dst, w, budget):
            st = ingest(src, dst, w)
            st2 = ingest(src, dst, w, route_budget=budget)  # compacted router
            sync = jax.jit(make_sync_vertices(sspec, pspec, mesh, "data"))
            sync_i = jax.jit(make_sync_vertices(sspec, pspec, mesh, "data",
                                                budget=budget,
                                                incremental=True))
            stf = sync(st)
            sti = sync_i(st, jnp.zeros((2,), jnp.int32))
            for a, b in zip(jax.tree.leaves(stf), jax.tree.leaves(sti)):
                assert np.array_equal(np.asarray(a), np.asarray(b))
            sti2 = sync_i(st2, jnp.zeros((2,), jnp.int32))
            sk = pack_keys(np.array([src[0]], np.uint64), 32)[0]
            d_ref = np.asarray(jax.jit(make_bfs(sspec, pspec, mesh, "data",
                                                m_cap, max_iters=70))(stf, sk))
            d_cmp = np.asarray(jax.jit(make_bfs(sspec, pspec, mesh, "data",
                m_cap, max_iters=70, frontier_budget=budget))(stf, sk))
            assert np.array_equal(d_ref, d_cmp), "bfs"
            assert np.array_equal(d_ref, np.asarray(jax.jit(make_bfs(
                sspec, pspec, mesh, "data", m_cap, max_iters=70,
                frontier_budget=budget))(sti2, sk))), "bfs routed state"
            p_ref = np.asarray(jax.jit(make_pagerank(sspec, pspec, mesh,
                "data", m_cap, iters=15))(stf))
            p_cmp = np.asarray(jax.jit(make_pagerank(sspec, pspec, mesh,
                "data", m_cap, iters=15, frontier_budget=budget))(stf))
            assert np.array_equal(p_ref, p_cmp), "pagerank"
            qk = pack_keys(ids[:16], 32)
            for k in (1, 2, 3):
                kw = dict(m_cap=m_cap) if k > 1 else {}
                a = np.asarray(jax.jit(make_khop_counts(sspec, pspec, mesh,
                    "data", k=k, **kw))(stf, qk))
                kwb = dict(kw, frontier_budget=budget) if k > 1 else kw
                b = np.asarray(jax.jit(make_khop_counts(sspec, pspec, mesh,
                    "data", k=k, **kwb))(stf, qk))
                assert np.array_equal(a, b), ("khop", k)
            return stf, d_ref
        # sparse frontier: a path graph -> one-vertex frontiers, compact hit
        n_path = 61
        psrc = ids[:n_path - 1]; pdst = ids[1:n_path]
        w = np.ones(n_path - 1, np.float32)
        stf, d_ref = check(psrc, pdst, w, budget=8)
        # path depths must follow the chain (single-shard reference)
        g = RadixGraph(n_max=2048, key_bits=32, expected_n=256, batch=1024,
                       pool_blocks=8192, block_size=8, dmax=2048)
        g.apply_ops(psrc, pdst, w)
        off = g.lookup(ids[:n_path])
        ref_d = np.asarray(A.bfs(g.snapshot(), jnp.int32(int(off[0])),
                                 max_iters=70))
        flat = {}
        from repro.dist.graph_engine import collect_owner_values
        dd = collect_owner_values(stf, d_ref, 2)
        for i, vid in enumerate(ids[:n_path]):
            assert int(dd[int(vid)]) == int(ref_d[int(off[i])])
        # full frontier: dense random graph + budget 2 -> fallback rounds
        B = 512
        src = rng.choice(ids, B); dst = rng.choice(ids, B)
        w = rng.uniform(0.5, 2, B).astype(np.float32)
        w[rng.random(B) < 0.1] = 0.0
        check(src, dst, w, budget=2)
        print("COMPACT-EXCHANGE-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                          "PYTHONPATH": "src"},
                         cwd=str(__import__("pathlib").Path(
                             __file__).resolve().parents[1]), timeout=600)
    assert "COMPACT-EXCHANGE-OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_distributed_analytics_subprocess():
    """Versioned read path over 4 placeholder devices: vertex sync, per-shard
    CSR snapshots, and level-synchronous BFS/PageRank with frontier/inflow
    exchange must match the single-shard reference algorithms."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.core.sort import SortSpec
        from repro.core.sort_optimizer import optimize_sort
        from repro.core import edgepool as ep
        from repro.core.keys import pack_keys
        from repro.core.radixgraph import RadixGraph
        from repro import analytics as A
        from repro.dist.graph_engine import (make_sharded_state,
            make_apply_edges, make_sync_vertices, make_snapshot, make_bfs,
            make_pagerank, collect_owner_values)
        mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
        cfg = optimize_sort(256, 32, 5)
        sspec = SortSpec.from_config(cfg, 1024)
        pspec = ep.PoolSpec(n_blocks=1024, block_size=8, k_max=32, dmax=256)
        state = make_sharded_state(sspec, pspec, 4, 1024)
        apply_fn = jax.jit(make_apply_edges(sspec, pspec, mesh, "data"))
        rng = np.random.default_rng(1)
        ids = rng.choice(2**32, 120, replace=False).astype(np.uint64)
        B = 1024
        src = rng.choice(ids, B); dst = rng.choice(ids, B)
        w = rng.uniform(0.5, 2, B).astype(np.float32)
        w[rng.random(B) < 0.1] = 0.0   # mixed stream incl. deletes
        state, dropped = apply_fn(state, pack_keys(src, 32),
                                  pack_keys(dst, 32), jnp.asarray(w),
                                  jnp.ones(B, bool))
        assert int(np.asarray(dropped).sum()) == 0
        state = jax.jit(make_sync_vertices(sspec, pspec, mesh, "data"))(state)
        m_cap = 4096
        snap_fn = jax.jit(make_snapshot(sspec, pspec, mesh, "data", m_cap))
        shard_snaps = snap_fn(state)
        # per-shard edge counts sum to the global live count
        g = RadixGraph(n_max=2048, key_bits=32, expected_n=256, batch=1024,
                       pool_blocks=8192, block_size=8, dmax=2048)
        g.apply_ops(src, dst, w)
        assert int(np.asarray(shard_snaps.m).sum()) == g.num_edges
        snap = g.snapshot(); off = g.lookup(ids)
        sk = pack_keys(np.array([src[0]], np.uint64), 32)[0]
        depth = jax.jit(make_bfs(sspec, pspec, mesh, "data", m_cap,
                                 max_iters=32))(state, sk)
        dd = collect_owner_values(state, np.asarray(depth), 4)
        s0 = int(g.lookup(np.array([src[0]], np.uint64))[0])
        ref_d = np.asarray(A.bfs(snap, jnp.int32(s0)))
        pr = jax.jit(make_pagerank(sspec, pspec, mesh, "data", m_cap,
                                   iters=25))(state)
        dp = collect_owner_values(state, np.asarray(pr), 4)
        ref_pr = np.asarray(A.pagerank(snap, iters=25))
        for i, vid in enumerate(ids):
            assert int(dd[int(vid)]) == int(ref_d[int(off[i])]), vid
            assert abs(float(dp[int(vid)]) -
                       float(ref_pr[int(off[i])])) < 1e-6, vid
        print("DIST-ANALYTICS-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                          "PYTHONPATH": "src"},
                         cwd=str(__import__("pathlib").Path(
                             __file__).resolve().parents[1]), timeout=600)
    assert "DIST-ANALYTICS-OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_pipelined_apply_engine_parity_subprocess():
    """One pipelined (K, B, ...) scanned program over 2 placeholder devices
    is BIT-EXACT vs K sequential per-batch ``make_apply_edges`` calls — on
    a hub-heavy stream whose overflow defrag fires MID-super-batch (tiny
    probe window, k_big=1), plus a ragged K' < K trailing super-batch, and
    equally under the compacted (route_budget) router."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.core.sort import SortSpec
        from repro.core.sort_optimizer import optimize_sort
        from repro.core import edgepool as ep
        from repro.core.keys import pack_keys
        from repro.dist.graph_engine import (make_sharded_state,
            make_apply_edges, make_apply_edges_pipelined)
        mesh = jax.make_mesh((2,), ("data",), axis_types=(AxisType.Auto,))
        cfg = optimize_sort(256, 32, 5)
        sspec = SortSpec.from_config(cfg, 1024)
        pspec = ep.PoolSpec(n_blocks=1024, block_size=8, k_max=32, dmax=256,
                            probe_width=8, k_big=1)
        rng = np.random.default_rng(9)
        ids = rng.choice(2**32, 100, replace=False).astype(np.uint64)
        hubs = ids[:6]                  # 6 hubs > k_big=1: defrag fallback
        B, NB = 256, 5                  # K=3 -> super-batches [3, 2]
        n_ops = B * NB
        src = hubs[np.arange(n_ops) % len(hubs)]
        dst = ids[rng.integers(0, len(ids), n_ops)]
        w = rng.uniform(0.5, 2, n_ops).astype(np.float32)
        w[rng.random(n_ops) < 0.1] = 0.0
        sks = np.asarray(pack_keys(src, 32)).reshape(NB, B, 2)
        dks = np.asarray(pack_keys(dst, 32)).reshape(NB, B, 2)
        ws = w.reshape(NB, B); ms = np.ones((NB, B), bool)
        for budget in (None, 64):
            seq = jax.jit(make_apply_edges(sspec, pspec, mesh, "data",
                                           route_budget=budget))
            pipe = jax.jit(make_apply_edges_pipelined(
                sspec, pspec, mesh, "data", route_budget=budget))
            st_a = make_sharded_state(sspec, pspec, 2, 1024)
            drop_a = np.zeros(2, np.int64)
            for i in range(NB):
                st_a, d = seq(st_a, jnp.asarray(sks[i]), jnp.asarray(dks[i]),
                              jnp.asarray(ws[i]), jnp.asarray(ms[i]))
                drop_a += np.asarray(d)
            st_b = make_sharded_state(sspec, pspec, 2, 1024)
            drop_b = np.zeros(2, np.int64)
            for lo, hi in ((0, 3), (3, 5)):      # ragged tail K'=2 < K=3
                st_b, d = pipe(st_b, jnp.asarray(sks[lo:hi]),
                               jnp.asarray(dks[lo:hi]),
                               jnp.asarray(ws[lo:hi]),
                               jnp.asarray(ms[lo:hi]))
                drop_b += np.asarray(d)
            assert np.array_equal(drop_a, drop_b), (budget, drop_a, drop_b)
            for a, b in zip(jax.tree.leaves(st_a), jax.tree.leaves(st_b)):
                assert np.array_equal(np.asarray(a), np.asarray(b)), budget
            defrags = int(np.asarray(st_b.pool.defrags).sum())
            assert defrags >= 1, "stream must exercise the mid-scan defrag"
        print("PIPELINED-PARITY-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                          "PYTHONPATH": "src"},
                         cwd=str(__import__("pathlib").Path(
                             __file__).resolve().parents[1]), timeout=600)
    assert "PIPELINED-PARITY-OK" in out.stdout, out.stderr[-2000:]
