import os
import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — tests must see the real (1-device)
# topology; only launch/dryrun*.py force placeholder devices (and the
# multishard test does so in a subprocess).

# hypothesis is an optional dep (pyproject test extras). When absent, install
# the deterministic mini stand-in BEFORE test modules import it, so property
# tests run with seeded examples instead of erroring at collection.
sys.path.insert(0, os.path.dirname(__file__))
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    import _mini_hypothesis
    sys.modules["hypothesis"] = _mini_hypothesis
    sys.modules["hypothesis.strategies"] = _mini_hypothesis.strategies


def pytest_configure(config):
    # registered in pyproject.toml as well; kept here so `pytest tests/...`
    # from any rootdir honours -m "not slow" without warnings
    config.addinivalue_line(
        "markers", "slow: long-running tests (multi-device subprocess)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
