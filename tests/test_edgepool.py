"""Snapshot-log edge storage vs an ordered Python oracle — the paper's core
semantics (insert/update/delete, compaction, MVCC) under property testing."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import edgepool as ep
from repro.core.radixgraph import RadixGraph


def mk(policy="snaplog", **kw):
    args = dict(n_max=256, key_bits=16, expected_n=64, batch=128,
                pool_blocks=4096, block_size=8, dmax=512, k_max=32,
                policy=policy)
    args.update(kw)
    return RadixGraph(**args)


ops_strategy = st.lists(
    st.tuples(st.integers(0, 30), st.integers(0, 30),
              st.sampled_from([0.0, 1.0, 2.5])),
    min_size=1, max_size=300)


@settings(max_examples=12, deadline=None)
@pytest.mark.parametrize("policy", ["snaplog", "grow", "sorted"])
@given(ops=ops_strategy)
def test_mixed_stream_matches_oracle(policy, ops):
    g = mk(policy)
    src = np.array([o[0] for o in ops], np.uint64)
    dst = np.array([o[1] for o in ops], np.uint64)
    w = np.array([o[2] for o in ops], np.float32)
    g.apply_ops(src, dst, w)
    oracle = {}
    for s, d, ww in ops:
        if ww == 0.0:
            oracle.pop((s, d), None)
        else:
            oracle[(s, d)] = ww
    assert g.num_edges == len(oracle)
    assert not g.overflowed
    for vid in sorted({o[0] for o in ops})[:8]:
        nb_ids, nb_w = g.neighbors([vid])[0]
        got = dict(zip(nb_ids.tolist(), nb_w.tolist()))
        exp = {d: ww for (s, d), ww in oracle.items() if s == vid}
        assert set(got) == set(exp)
        for k in exp:
            assert got[k] == pytest.approx(exp[k])


def test_compaction_triggers_and_preserves(rng):
    g = mk(dmax=256)
    # hammer a single vertex with updates so compaction fires repeatedly
    dsts = rng.integers(0, 40, 600).astype(np.uint64)
    ws = rng.uniform(1, 2, 600).astype(np.float32)
    g.apply_ops(np.zeros(600, np.uint64), dsts, ws)
    oracle = {}
    for d, w in zip(dsts, ws):
        oracle[int(d)] = float(w)
    ids, w = g.neighbors([0])[0]
    got = dict(zip(ids.tolist(), w.tolist()))
    assert set(got) == set(oracle)
    # capacity discipline: cap <= 2 * ceil(live/bs) * bs + slack blocks
    off = int(g.lookup(np.array([0], np.uint64))[0])
    cap = int(g.state.vt.cap[off])
    live = len(oracle)
    assert cap <= 4 * max(live, 8)


def test_mvcc_read_ts(rng):
    g = mk()
    g.apply_ops(np.array([1, 1], np.uint64), np.array([2, 3], np.uint64),
                np.array([1.0, 1.0], np.float32))
    ts1 = g.current_ts
    g.apply_ops(np.array([1, 1], np.uint64), np.array([2, 4], np.uint64),
                np.array([0.0, 5.0], np.float32))  # delete (1,2), add (1,4)
    # current view
    ids, w = g.neighbors([1])[0]
    assert set(ids.tolist()) == {3, 4}
    # historical view at ts1: (1,2) alive, (1,4) absent
    ids, w = g.neighbors([1], read_ts=ts1)[0]
    assert set(ids.tolist()) == {2, 3}


def test_vertex_delete_hides_edges_and_recycles(rng):
    g = mk()
    g.apply_ops(np.array([1, 2, 3], np.uint64), np.array([2, 3, 1], np.uint64),
                np.array([1, 1, 1], np.float32))
    g.delete_vertices([2])
    assert g.lookup(np.array([2], np.uint64))[0] == -1
    # edges from/to 2 invisible
    assert g.num_edges == 1  # only (3,1)
    ids, _ = g.neighbors([1])[0]
    assert ids.tolist() == []
    # defrag recycles the row; re-adding works
    g.defrag()
    g.add_vertices([2])
    assert g.lookup(np.array([2], np.uint64))[0] >= 0
    assert g.num_edges == 1


def test_defrag_is_semantic_noop(rng):
    g = mk()
    src = rng.integers(0, 30, 500).astype(np.uint64)
    dst = rng.integers(0, 30, 500).astype(np.uint64)
    w = rng.uniform(0.5, 2, 500).astype(np.float32)
    w[rng.random(500) < 0.2] = 0
    g.apply_ops(src, dst, w)
    before = {tuple(x) for x in np.stack(
        [np.asarray(g.snapshot().dst)[:g.num_edges]]).T.tolist()}
    m0 = g.num_edges
    g.defrag()
    assert g.num_edges == m0
    snap = g.snapshot()
    after = {tuple(x) for x in np.stack(
        [np.asarray(snap.dst)[:m0]]).T.tolist()}
    assert before == after


def test_amortized_o1_defrag_count(rng):
    """Theorem 2 proxy: the number of defrags grows logarithmically, not
    linearly, with the op count (the pool's ``defrags`` counter is exact —
    each global rebuild increments it once)."""
    g = mk(pool_blocks=2048)
    n_batches = 0
    for wave in range(8):
        src = rng.integers(0, 50, 256).astype(np.uint64)
        dst = rng.integers(0, 50, 256).astype(np.uint64)
        w = rng.uniform(0.5, 2, 256).astype(np.float32)
        g.apply_ops(src, dst, w)
        n_batches += 1
    assert not g.overflowed
    # far fewer rebuilds than batches (2x capacity growth => O(log d) per
    # vertex); an explicit defrag() adds exactly one
    assert g.num_defrags < n_batches
    before = g.num_defrags
    g.defrag()
    assert g.num_defrags == before + 1


# --------------------------------------------------------------------------
# mixed streams with undirected=True: the interleaved directions must
# preserve stream order (op i's two orientations land at timestamps 2i, 2i+1)
# --------------------------------------------------------------------------

def _undirected_oracle(ops):
    oracle = {}
    for s, d, w in ops:
        for a, b in ((int(s), int(d)), (int(d), int(s))):
            if w == 0.0:
                oracle.pop((a, b), None)
            else:
                oracle[(a, b)] = float(w)
    return oracle


def _check_against_oracle(g, oracle, vids):
    assert g.num_edges == len(oracle)
    for vid in vids:
        nb_ids, nb_w = g.neighbors([vid])[0]
        got = dict(zip(nb_ids.tolist(), nb_w.tolist()))
        exp = {b: w for (a, b), w in oracle.items() if a == vid}
        assert got.keys() == exp.keys(), (vid, got, exp)
        for k in exp:
            assert got[k] == pytest.approx(exp[k])


def test_mixed_stream_undirected_interleaved_order():
    g = mk(undirected=True)
    # one batch exercising every ordering hazard:
    #  - update through the REVERSE orientation (op 2 overwrites op 0's edge)
    #  - delete after update (op 3 kills both directions of (1,2))
    #  - delete through the reverse orientation (op 5 kills op 4's edge)
    #  - re-insert after delete of the same pair (op 6)
    #  - self-loop (ops 2i/2i+1 collapse to one entry)
    ops = [(1, 2, 1.0), (3, 1, 2.0), (2, 1, 5.0), (1, 2, 0.0),
           (4, 2, 1.5), (2, 4, 0.0), (1, 2, 3.0), (2, 3, 7.0), (5, 5, 9.0)]
    g.apply_ops(np.array([o[0] for o in ops], np.uint64),
                np.array([o[1] for o in ops], np.uint64),
                np.array([o[2] for o in ops], np.float32))
    oracle = _undirected_oracle(ops)
    assert oracle == {(1, 2): 3.0, (2, 1): 3.0, (1, 3): 2.0, (3, 1): 2.0,
                      (2, 3): 7.0, (3, 2): 7.0, (5, 5): 9.0}
    _check_against_oracle(g, oracle, [1, 2, 3, 4, 5])
    assert not g.overflowed and g.dropped_ops == 0


# --------------------------------------------------------------------------
# live-edge accounting on the probe-free ingest fast path
# --------------------------------------------------------------------------

live_ops_strategy = st.lists(
    st.tuples(st.integers(0, 20), st.integers(0, 20),
              st.sampled_from([0.0, 0.0, 1.0, 2.5, 7.0])),
    min_size=1, max_size=120)


@settings(max_examples=15, deadline=None)
@given(ops=live_ops_strategy, chunk=st.integers(1, 60))
def test_live_counter_exact_on_in_window_streams(ops, chunk):
    """Property: across mixed insert/update/delete streams applied in
    arbitrary chunkings, ``live_m`` stays EXACT (never dirty) while every
    touched vertex fits the probe window, and matches a full recount."""
    g = mk(probe_width=64)
    for lo in range(0, len(ops), chunk):
        part = ops[lo:lo + chunk]
        g.apply_ops(np.array([o[0] for o in part], np.uint64),
                    np.array([o[1] for o in part], np.uint64),
                    np.array([o[2] for o in part], np.float32))
    oracle = {}
    for s, d, ww in ops:
        if ww == 0.0:
            oracle.pop((s, d), None)
        else:
            oracle[(s, d)] = ww
    assert int(g.state.pool.live_dirty) == 0
    assert int(g.state.pool.live_m) == len(oracle)
    assert g.num_edges == len(oracle)
    assert g.num_edges == int(g.snapshot().m)   # vs full rebuild
    assert not g.overflowed


def test_bounded_probe_flags_dirty_past_window():
    """A probed pair whose owner outgrew the probe WINDOW (and was not
    compacted this batch) must flag the counter dirty — the newest entry
    may sit past the window — and the recount must heal it."""
    g = mk(probe_width=16, dmax=256)
    g.apply_ops(np.zeros(20, np.uint64), np.arange(1, 21, dtype=np.uint64),
                np.ones(20, np.float32))
    assert int(g.state.pool.live_dirty) == 0    # first touch: probe size 0
    assert g.num_edges == 20
    # update one pair: pre-append size (20) > window (16), no compaction
    g.apply_ops(np.zeros(1, np.uint64), np.array([5], np.uint64),
                np.array([2.0], np.float32))
    assert int(g.state.pool.live_dirty) == 1
    assert g.num_edges == 20                    # recount, not 21
    assert int(g.state.pool.live_dirty) == 0    # written back


def test_compaction_fold_keeps_over_window_vertex_exact():
    """A vertex past the probe window that IS compacted in the same batch
    hands the probe its liveness fold: the counter stays exact (no dirty)
    even though the window alone could not decide pair liveness."""
    g = mk(probe_width=16, dmax=256)
    g.apply_ops(np.zeros(20, np.uint64), np.arange(1, 21, dtype=np.uint64),
                np.ones(20, np.float32))
    # cap is now 24 (3 blocks of 8): 5 incoming ops overflow -> tier-L
    # compaction of vertex 0 (size 20 > window 16) with fold
    ops_d = np.array([3, 5, 21, 22, 4], np.uint64)
    ops_w = np.array([9.0, 0.0, 1.0, 1.0, 9.0], np.float32)
    g.apply_ops(np.zeros(5, np.uint64), ops_d, ops_w)
    assert int(g.state.pool.live_dirty) == 0
    # 20 - 1 delete + 2 inserts = 21, updates don't change the count
    assert int(g.state.pool.live_m) == 21
    assert g.num_edges == int(g.snapshot().m) == 21


def test_pallas_append_path_matches_ref_path(rng):
    """The fused Pallas append kernel (interpret mode) drives the same
    graph evolution as the jnp scatter + windowed probe path — and its
    full-extent probe never flags the counter dirty."""
    ids = rng.integers(0, 12, (150, 2)).astype(np.uint64)
    ws = rng.uniform(0.5, 2, 150).astype(np.float32)
    ws[rng.random(150) < 0.3] = 0.0
    kw = dict(n_max=64, key_bits=16, expected_n=32, batch=32,
              pool_blocks=128, block_size=8, dmax=64, k_max=8)
    g_ref = RadixGraph(**kw)
    g_pal = RadixGraph(append_impl="pallas", **kw)
    for lo in range(0, 150, 50):
        for g in (g_ref, g_pal):
            g.apply_ops(ids[lo:lo + 50, 0], ids[lo:lo + 50, 1],
                        ws[lo:lo + 50])
    assert int(g_pal.state.pool.live_dirty) == 0
    assert g_ref.num_edges == g_pal.num_edges
    assert np.array_equal(np.asarray(g_ref.snapshot().dst),
                          np.asarray(g_pal.snapshot().dst))
    for vid in range(12):
        a = g_ref.neighbors([vid])[0]
        b = g_pal.neighbors([vid])[0]
        assert set(a[0].tolist()) == set(b[0].tolist())


# --------------------------------------------------------------------------
# streaming defrag: bit-identical to the dense entry-scatter rebuild
# --------------------------------------------------------------------------

defrag_ops_strategy = st.lists(
    st.tuples(st.integers(0, 25), st.integers(0, 25),
              st.sampled_from([0.0, 1.0, 2.5])),
    min_size=1, max_size=250)

# one compile per pool config, shared across property examples
import jax  # noqa: E402

_stream_defrag = jax.jit(ep.defrag, static_argnums=0)
_dense_defrag = jax.jit(ep._defrag_dense, static_argnums=0)


def _assert_states_equal(a, b, ctx):
    pa, va = a
    pb, vb = b
    for name in pa._fields:
        assert np.array_equal(np.asarray(getattr(pa, name)),
                              np.asarray(getattr(pb, name))), (ctx, name)
    for name in va._fields:
        assert np.array_equal(np.asarray(getattr(va, name)),
                              np.asarray(getattr(vb, name))), (ctx, name)


@settings(max_examples=8, deadline=None)
@pytest.mark.parametrize("policy", ["snaplog", "grow", "sorted"])
@given(ops=defrag_ops_strategy, dele=st.lists(st.integers(0, 25), max_size=3),
       inc_v=st.lists(st.tuples(st.integers(0, 25), st.integers(1, 40)),
                      max_size=4))
def test_streaming_defrag_bit_identical_to_dense(policy, ops, dele, inc_v):
    """Property: across policies, tombstones, deleted vertices, and
    arbitrary pending-incoming hints, the streaming block-row rebuild
    produces the SAME pool and vertex table — every array, including the
    ``live_m`` resync — as the dense entry-scatter reference."""
    g = mk(policy)
    src = np.array([o[0] for o in ops], np.uint64)
    dst = np.array([o[1] for o in ops], np.uint64)
    w = np.array([o[2] for o in ops], np.float32)
    g.apply_ops(src, dst, w)
    if dele:
        g.delete_vertices(np.unique(np.array(dele, np.uint64)))
    incoming = np.zeros((g.n_max,), np.int32)
    for vid, cnt in inc_v:
        off = int(g.lookup(np.array([vid], np.uint64))[0])
        if off >= 0:
            incoming[off] += cnt
    pool, vt = g.state.pool, g.state.vt
    inc = jnp.asarray(incoming)
    stream = _stream_defrag(g.pool_spec, pool, vt, inc)
    dense = _dense_defrag(g.pool_spec, pool, vt, inc)
    _assert_states_equal(stream, dense, policy)
    # the rebuild is the live counter's resync point: exact, not dirty
    assert int(stream[0].live_dirty) == 0
    g.defrag()
    assert g.num_edges == int(g.snapshot().m)


def test_streaming_defrag_falls_back_past_dmax(rng):
    """A vertex grown past dmax (post-jumbo) cannot ride the size
    segments: the dispatcher must fall back to the dense rebuild and
    still produce the identical state."""
    g = mk(dmax=64, k_max=8, k_big=2, pool_blocks=8192)
    # one vertex with > dmax distinct live edges: jumbo batches rebuild
    # it via defrag, after which size (= live degree) exceeds dmax
    dsts = np.arange(1, 101, dtype=np.uint64)
    g.apply_ops(np.zeros(100, np.uint64), dsts, np.ones(100, np.float32))
    off = int(g.lookup(np.array([0], np.uint64))[0])
    assert int(g.state.vt.size[off]) > 64
    pool, vt = g.state.pool, g.state.vt
    inc = jnp.zeros((g.n_max,), jnp.int32)
    _assert_states_equal(_stream_defrag(g.pool_spec, pool, vt, inc),
                         _dense_defrag(g.pool_spec, pool, vt, inc),
                         "past-dmax")
    ids, _ = g.neighbors([0], width=128)[0]
    assert set(ids.tolist()) == set(range(1, 101))


def test_defrag_pending_hint_presizes_extents():
    """An explicit defrag given the pending batch's sources must pre-size
    the rebuilt extents so the batch then rides the fast path; without
    the hint the same batch immediately re-overflows into a second
    rebuild (the hub-stream failure mode the hint exists for)."""
    def build():
        # batch covers the whole follow-up stream so every hub overflows
        # in ONE device batch (6 > k_max forces the rebuild fallback)
        g = mk(k_max=4, k_big=2, batch=256)
        hubs = np.arange(6, dtype=np.uint64)
        src = np.repeat(hubs, 16)
        dst = np.tile(np.arange(100, 116, dtype=np.uint64), 6)
        g.apply_ops(src, dst, np.ones(96, np.float32))
        return g, hubs
    # the follow-up batch: 40 fresh edges per hub — more than the 2d
    # discipline reserves, and 6 overflowing hubs exceed k_max/k_big
    g, hubs = build()
    src2 = np.repeat(hubs, 40)
    dst2 = np.tile(np.arange(200, 240, dtype=np.uint64), 6)
    w2 = np.ones(240, np.float32)

    g.defrag(pending_src=src2)          # hint: pre-size for the batch
    d0 = g.num_defrags
    g.apply_ops(src2, dst2, w2)
    assert g.num_defrags == d0          # no re-overflow rebuild
    assert g.dropped_ops == 0 and not g.overflowed

    g2, _ = build()
    g2.defrag()                         # control: no hint
    d0 = g2.num_defrags
    g2.apply_ops(src2, dst2, w2)
    assert g2.num_defrags == d0 + 1     # immediate re-overflow rebuild
    assert g.num_edges == g2.num_edges


def test_append_tiles_scanned_bounded_by_touched_extents():
    """The bounded append's tile counter must track the batches'
    footprints: a tiny graph in a huge pool (32 tiles) scans a handful of
    tiles per batch, never batches x pool tiles."""
    g = mk()                    # pool_blocks=4096, bs=8 -> 32 append tiles
    rng = np.random.default_rng(0)
    n_batches = 6
    for i in range(n_batches):
        src = rng.integers(0, 16, 50).astype(np.uint64)
        dst = rng.integers(0, 16, 50).astype(np.uint64)
        g.apply_ops(src, dst, np.ones(50, np.float32))
    assert g.tiles_scanned >= n_batches          # every batch lands slots
    assert g.tiles_scanned <= 4 * n_batches      # touched-extent bound
    # dense scanning would have cost batches x n_tiles
    assert g.tiles_scanned < n_batches * 32


def test_mixed_stream_undirected_order_across_batches(rng):
    """Same-pair churn split across apply_ops calls (and batch-pad
    boundaries): the global clock must keep the interleaved directions
    ordered."""
    g = mk(undirected=True, batch=64)
    ids = rng.integers(0, 16, (400, 2)).astype(np.uint64)
    ws = rng.uniform(0.5, 2, 400).astype(np.float32)
    ws[rng.random(400) < 0.3] = 0.0
    all_ops = [(int(s), int(d), float(w))
               for (s, d), w in zip(ids, ws)]
    for lo in range(0, 400, 100):  # 4 calls, each multiple padded batches
        chunk = all_ops[lo:lo + 100]
        g.apply_ops(np.array([o[0] for o in chunk], np.uint64),
                    np.array([o[1] for o in chunk], np.uint64),
                    np.array([o[2] for o in chunk], np.float32))
    oracle = _undirected_oracle(all_ops)
    _check_against_oracle(g, oracle, sorted({o[0] for o in all_ops}))
    assert not g.overflowed and g.dropped_ops == 0
