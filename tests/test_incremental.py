"""Incremental epoch-delta analytics: the advance == scratch parity
property over random mixed streams, every forced-fallback path, and the
service's bounded warm-state / epoch-pin retention.

Streams are applied SYMMETRICALLY (each op in both directions): the
paper treats graphs as undirected and the WCC propagation assumes it —
on a one-way edge set its directional fixed point is not the component
labeling, so parity against the union-find advance would be vacuous.
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import AnalyticsOp, OpBatch, make_store

CAPS = dict(n_max=512, pool_blocks=1024, block_size=8, dmax=256, k_max=64,
            batch=128)


def _store(max_delta_frac=0.9):
    return make_store("local", key_bits=32, expected_n=64,
                      undirected=False, m_cap=2048,
                      max_delta_frac=max_delta_frac, **CAPS)


def _ops(src):
    return [AnalyticsOp("pagerank", dict(iters=200, tol=1e-7)),
            AnalyticsOp("wcc", {}),
            AnalyticsOp("bfs", dict(source=src)),
            AnalyticsOp("sssp", dict(source=src)),
            AnalyticsOp("degree_map", {}),
            AnalyticsOp("num_edges", {})]


def _sym(s, d, w):
    return (np.concatenate([s, d]), np.concatenate([d, s]),
            np.concatenate([w, w]))


def _max_err(a, b):
    if isinstance(a, dict):
        if set(a) != set(b):
            return float("inf")
        if not a:
            return 0.0
        ks = sorted(a)
        va = np.array([float(a[k]) for k in ks], np.float64)
        vb = np.array([float(b[k]) for k in ks], np.float64)
        return float(np.abs(va - vb).max())
    return abs(float(a) - float(b))


def _check_parity(name, rs, ri):
    err = _max_err(rs.value, ri.value)
    tol = 1e-5 if name == "pagerank" else 0.0
    assert err <= tol, (name, ri.mode, ri.reason, err)


def _base_load(store, rng, nv=40, n_pairs=120):
    ids = rng.choice(2 ** 32, nv, replace=False).astype(np.uint64)
    s = ids[rng.integers(0, nv, n_pairs)]
    d = ids[rng.integers(0, nv, n_pairs)]
    w = rng.uniform(1.0, 2.0, n_pairs).astype(np.float32)
    store.apply(OpBatch.edges(*_sym(s, d, w)))
    return ids


# ---- the property: advance is exact on every path, fallback included ----

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_advance_matches_scratch_local(seed):
    """Random mixed insert/update/delete streams on ``LocalStore``: every
    epoch, every algorithm, ``analytics_advance`` equals the scratch run
    (exactly; <1e-5 for tolerance-mode PageRank). Clean (monotone) epochs
    must actually take the incremental path; delete epochs must drive the
    guarded algorithms through their fallback — and still answer right."""
    rng = np.random.default_rng(seed)
    store = _store()
    ids = _base_load(store, rng)
    ops = _ops(int(ids[0]))
    live = set()        # forward pairs known live -> deletes are effective

    ep = store.capture()
    warm = {o.name: store.analytics_result(o, ep) for o in ops}
    for k in range(3):
        dirty = bool(rng.random() < 0.4)
        n = int(rng.integers(5, 25))
        lo, hi = 0.5 * 0.5 ** k, 0.9 * 0.5 ** k   # decreasing bands:
        s = ids[rng.integers(0, len(ids), n)]     # updates never increase
        d = ids[rng.integers(0, len(ids), n)]
        w = rng.uniform(lo, hi, n).astype(np.float32)
        fresh = set(zip(s.tolist(), d.tolist()))
        dels = set()
        if dirty and live:
            # only pre-batch live pairs: a pair inserted and tombstoned in
            # the SAME batch nets to no change vs the previous epoch, so
            # it would not put a delete in the delta
            cand = sorted(live)
            take = rng.integers(0, len(cand), max(1, n // 4))
            dels = {cand[i] for i in take}
            ds = np.array([p[0] for p in dels], np.uint64)
            dd = np.array([p[1] for p in dels], np.uint64)
            # tombstones append AFTER the inserts -> in-batch they win
            s = np.concatenate([s, ds])
            d = np.concatenate([d, dd])
            w = np.concatenate([w, np.zeros(len(dels), np.float32)])
        live = (live | fresh) - dels
        dirty = bool(dels)
        store.apply(OpBatch.edges(*_sym(s, d, w)))
        cur = store.capture()
        for o in ops:
            ri = store.analytics_advance(o, warm[o.name], cur)
            rs = store.analytics_result(o, cur)
            _check_parity(o.name, rs, ri)
            if not dirty:
                assert ri.mode == "incremental", (o.name, ri.reason)
            elif o.name in ("bfs", "wcc", "sssp"):
                assert ri.mode == "scratch" and ri.reason, (o.name, ri)
            warm[o.name] = ri


# ---- every fallback reason, deterministically ----

def test_fallback_reasons_local():
    rng = np.random.default_rng(7)
    store = _store()
    ids = _base_load(store, rng)
    # known-live pairs so the tombstone / update below are EFFECTIVE
    # changes in the delta, not no-ops on absent edges
    store.apply(OpBatch.edges(*_sym(ids[[0, 0]], ids[[1, 2]],
                                    np.array([0.8, 0.5], np.float32))))
    op = AnalyticsOp("bfs", dict(source=int(ids[0])))
    ep = store.capture()
    warm = store.analytics_result(op, ep)

    # deletes -> the monotone advance refuses (but answers exactly)
    store.apply(OpBatch.edges(*_sym(ids[:1], ids[1:2],
                                    np.zeros(1, np.float32))))
    cur = store.capture()
    ri = store.analytics_advance(op, warm, cur)
    assert (ri.mode, ri.reason) == ("scratch", "advance-refused")
    _check_parity("bfs", store.analytics_result(op, cur), ri)
    warm, ep = ri, cur

    # a weight increase only breaks SSSP's monotonicity
    sop = AnalyticsOp("sssp", dict(source=int(ids[0])))
    swarm = store.analytics_result(sop, ep)
    store.apply(OpBatch.edges(*_sym(ids[:1], ids[2:3],       # 0.5 -> 9.0
                                    np.full(1, 9.0, np.float32))))
    cur = store.capture()
    ri = store.analytics_advance(sop, swarm, cur)
    assert (ri.mode, ri.reason) == ("scratch", "advance-refused")
    ri2 = store.analytics_advance(op, warm, cur)    # BFS shrugs it off
    assert ri2.mode == "incremental", ri2.reason
    warm, ep = ri2, cur

    # vertex events invalidate untouched rows' in-edges -> window refusal
    store.apply(OpBatch.delete_vertices(ids[5:6]))
    cur = store.capture()
    ri = store.analytics_advance(op, warm, cur)
    assert (ri.mode, ri.reason) == ("scratch", "vertex-event")
    warm, ep = ri, cur

    # oversized delta -> refused by the frac guard
    tight = _store(max_delta_frac=0.01)
    tids = _base_load(tight, np.random.default_rng(8))
    top = AnalyticsOp("num_edges", {})
    twarm = tight.analytics_result(top, tight.capture())
    s = tids[np.arange(30) % len(tids)]
    d = tids[(np.arange(30) * 7 + 1) % len(tids)]
    tight.apply(OpBatch.edges(*_sym(s, d, np.full(30, 0.3, np.float32))))
    ri = tight.analytics_advance(top, twarm, tight.capture())
    assert (ri.mode, ri.reason) == ("scratch", "delta-too-large")

    # defrag recycles rows -> warm arrays misaligned -> window refusal.
    # (A write must follow: defrag alone keeps the logical seq, and an
    # equal-seq advance legitimately returns the warm result as-is.)
    store.graph.defrag()
    same = store.analytics_advance(op, warm, store.capture())
    assert same is warm                 # logically unchanged epoch
    store.apply(OpBatch.edges(*_sym(ids[:1], ids[3:4],
                                    np.full(1, 0.2, np.float32))))
    cur = store.capture()
    ri = store.analytics_advance(op, warm, cur)
    assert (ri.mode, ri.reason) == ("scratch", "defrag")
    _check_parity("bfs", store.analytics_result(op, cur), ri)


def test_fixed_iteration_pagerank_never_advances():
    """Without ``tol`` the registry keeps the bit-compatible fixed-iters
    scratch path: ranks are path-dependent, so the advance refuses."""
    rng = np.random.default_rng(11)
    store = _store()
    ids = _base_load(store, rng)
    op = AnalyticsOp("pagerank", dict(iters=20))
    warm = store.analytics_result(op, store.capture())
    store.apply(OpBatch.edges(*_sym(ids[:2], ids[3:5],
                                    np.full(2, 0.4, np.float32))))
    ri = store.analytics_advance(op, warm, store.capture())
    assert (ri.mode, ri.reason) == ("scratch", "advance-refused")


def test_scalar_advances_survive_deletes():
    """degree/num_edges advance through delete epochs (no guard) and stay
    exact — the delta records net per-pair changes."""
    rng = np.random.default_rng(13)
    store = _store()
    ids = _base_load(store, rng)
    store.apply(OpBatch.edges(*_sym(ids[:3], ids[4:7],      # known live
                                    np.full(3, 0.7, np.float32))))
    ops = [AnalyticsOp("degree_map", {}), AnalyticsOp("num_edges", {})]
    ep = store.capture()
    warm = {o.name: store.analytics_result(o, ep) for o in ops}
    store.apply(OpBatch.edges(*_sym(ids[:3], ids[4:7],      # tombstone
                                    np.zeros(3, np.float32))))
    cur = store.capture()
    for o in ops:
        ri = store.analytics_advance(o, warm[o.name], cur)
        assert ri.mode == "incremental", (o.name, ri.reason)
        _check_parity(o.name, store.analytics_result(o, cur), ri)


# ---- bounded retention: warm LRU + refcounted epoch pins ----

def test_service_retention_plateaus():
    """A long write/query stream with more distinct analytics keys than
    ``max_warm_states``: evictions must release their epoch pins, so the
    store's retained-version count plateaus at the cap (+ the sealed
    epoch and the in-flight chain head) instead of growing per epoch."""
    from repro.serve.graph_service import GraphQueryService
    rng = np.random.default_rng(17)
    store = _store()
    ids = _base_load(store, rng)
    svc = GraphQueryService(store, seal_every=1, max_warm_states=3,
                            write_batch=64)
    retained = []
    for i in range(16):
        s = ids[rng.integers(0, len(ids), 8)]
        d = ids[rng.integers(0, len(ids), 8)]
        w = rng.uniform(0.1, 0.9, 8).astype(np.float32)
        svc.submit_update(*_sym(s, d, w))
        # 6 distinct warm keys churn a 3-deep LRU every epoch
        svc.submit_query("bfs", source=int(ids[i % 6]))
        svc.submit_query("pagerank", tol=1e-7, iters=200)
        svc.run()
        retained.append(svc.stats["retained_epochs"])
    assert svc.stats["warm_evictions"] > 0
    assert svc.stats["analytics_incremental"] > 0
    # plateau, not growth: the cap bounds the tail, and the count stops
    # tracking the epoch counter entirely
    assert max(retained[8:]) <= svc.max_warm_states + 2, retained
    assert retained[-1] <= svc.max_warm_states + 2, retained


def test_service_memo_identity_and_modes():
    """Within one sealed epoch the memo returns the same object; across
    seals the warm chain advances (mode counters prove the path)."""
    from repro.serve.graph_service import GraphQueryService
    rng = np.random.default_rng(19)
    store = _store()
    ids = _base_load(store, rng)
    svc = GraphQueryService(store, seal_every=0, max_warm_states=4)
    t1 = svc.submit_query("wcc")
    svc.step()
    t2 = svc.submit_query("wcc")
    svc.step()
    assert svc.results[t1] is svc.results[t2]
    assert svc.stats["analytics_scratch"] == 1
    svc.submit_update(*_sym(ids[:2], ids[3:5],
                            np.full(2, 0.7, np.float32)))
    svc.step()
    svc.seal_epoch()
    t3 = svc.submit_query("wcc")
    svc.step()
    assert svc.stats["analytics_incremental"] == 1
    assert set(svc.results[t3]) >= set(svc.results[t1])


# ---- cross-backend: the sharded warm programs (subprocess, 2 devices) ----

@pytest.mark.slow
def test_sharded_advance_parity_subprocess():
    """2-shard ShardedStore: warm mesh programs (BFS/WCC/SSSP/PageRank)
    and per-shard host advances (degree/num_edges) equal their scratch
    runs on clean epochs, take the incremental path, and fall back with
    the guard's reason on a delete epoch — still answering exactly."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import numpy as np
        from repro.api import AnalyticsOp, OpBatch, make_store

        def sym(s, d, w):
            return (np.concatenate([s, d]), np.concatenate([d, s]),
                    np.concatenate([w, w]))

        def err_of(a, b):
            if isinstance(a, dict):
                if set(a) != set(b):
                    return float("inf")
                ks = sorted(a)
                return max((abs(float(a[k]) - float(b[k])) for k in ks),
                           default=0.0)
            return abs(float(a) - float(b))

        rng = np.random.default_rng(23)
        store = make_store("sharded", n_shards=2, n_per_shard=2048,
                           expected_n=256, pool_blocks=4096, block_size=16,
                           k_max=64, dmax=512, batch=128, query_batch=64,
                           m_cap=4096, max_delta_frac=0.9)
        ids = rng.choice(2 ** 32, 64, replace=False).astype(np.uint64)
        s = ids[rng.integers(0, 64, 400)]
        d = ids[rng.integers(0, 64, 400)]
        w = rng.uniform(1.0, 2.0, 400).astype(np.float32)
        store.apply(OpBatch.edges(*sym(s, d, w)))
        ops = [AnalyticsOp("pagerank", dict(iters=200, tol=1e-7)),
               AnalyticsOp("wcc", {}),
               AnalyticsOp("bfs", dict(source=int(ids[0]))),
               AnalyticsOp("sssp", dict(source=int(ids[0]))),
               AnalyticsOp("degree_map", {}),
               AnalyticsOp("num_edges", {})]
        ep = store.capture()
        warm = {o.name: store.analytics_result(o, ep) for o in ops}
        for k in range(2):                      # clean monotone epochs
            lo, hi = 0.5 * 0.5 ** k, 0.9 * 0.5 ** k
            s = ids[rng.integers(0, 64, 20)]
            d = ids[rng.integers(0, 64, 20)]
            w = rng.uniform(lo, hi, 20).astype(np.float32)
            store.apply(OpBatch.edges(*sym(s, d, w)))
            cur = store.capture()
            for o in ops:
                ri = store.analytics_advance(o, warm[o.name], cur)
                rs = store.analytics_result(o, cur)
                assert ri.mode == "incremental", (o.name, ri.reason)
                e = err_of(rs.value, ri.value)
                assert e <= (1e-5 if o.name == "pagerank" else 0.0), \\
                    (o.name, e)
                warm[o.name] = ri
        store.apply(OpBatch.edges(*sym(s[:4], d[:4],       # delete epoch
                                       np.zeros(4, np.float32))))
        cur = store.capture()
        for o in ops:
            ri = store.analytics_advance(o, warm[o.name], cur)
            rs = store.analytics_result(o, cur)
            e = err_of(rs.value, ri.value)
            assert e <= (1e-5 if o.name == "pagerank" else 0.0), (o.name, e)
            if o.name in ("bfs", "wcc", "sssp"):
                assert ri.mode == "scratch" and ri.reason == "deletes", \\
                    (o.name, ri.mode, ri.reason)
            else:
                assert ri.mode == "incremental", (o.name, ri.reason)
        print("PARITY-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                         "PYTHONPATH": "src"},
                         cwd=str(__import__("pathlib").Path(
                             __file__).resolve().parents[1]), timeout=900)
    assert "PARITY-OK" in out.stdout, out.stderr[-3000:]
