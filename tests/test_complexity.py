"""Paper Table 3 complexity discipline — structural assertions.

We cannot wall-clock asymptotics on a noisy CPU, so we assert the structural
facts the complexities follow from:
  locate/insert/delete_v = O(lglg u): the SORT descent length is the layer
    count, fixed at construction;
  insert/update/delete_e = O(1) amortized: appends touch one slot; the
    capacity discipline (cap <= 2x live + block slack) bounds compaction
    work per Theorem 2; pool growth is bounded by ops;
  get_ngbrs = O(d): reads exactly the vertex's extent.
"""
import math

import numpy as np
import pytest

from repro.core.radixgraph import RadixGraph
from repro.core.sort_optimizer import optimize_sort


def test_sort_depth_is_lglg_u():
    for x in (16, 32, 64):
        l = max(2, round(math.log2(x)))
        cfg = optimize_sort(10 ** 5, x, l)
        assert len(cfg.fanout_bits) <= l          # pruning only shrinks
        assert sum(cfg.fanout_bits) == x          # full key consumed


def test_edge_append_touches_one_slot_per_op(rng):
    """Pool occupancy grows by exactly the op count between compactions."""
    g = RadixGraph(n_max=256, key_bits=16, expected_n=64, batch=64,
                   pool_blocks=8192, block_size=8, dmax=1024)
    sizes = []
    for wave in range(6):
        src = rng.integers(0, 8, 64).astype(np.uint64)
        dst = rng.integers(0, 64, 64).astype(np.uint64)
        g.add_edges(src, dst, rng.uniform(1, 2, 64).astype(np.float32))
        sizes.append(int(np.sum(np.asarray(g.state.vt.size))))
    # each wave appends <= 64 net entries (compaction only shrinks sizes)
    for a, b in zip(sizes, sizes[1:]):
        assert b - a <= 64


def test_capacity_discipline_bounds_amortized_work(rng):
    """cap_u <= 2*ceil(live/bs)*bs + incoming slack for every vertex
    (Theorem 2's precondition) after arbitrary mixed traffic."""
    g = RadixGraph(n_max=256, key_bits=16, expected_n=64, batch=128,
                   pool_blocks=8192, block_size=8, dmax=1024)
    for _ in range(5):
        src = rng.integers(0, 16, 128).astype(np.uint64)
        dst = rng.integers(0, 64, 128).astype(np.uint64)
        w = rng.uniform(0, 2, 128).astype(np.float32)
        w[rng.random(128) < 0.3] = 0
        g.apply_ops(src, dst, w)
    vt = g.state.vt
    size = np.asarray(vt.size)
    cap = np.asarray(vt.cap)
    deg = np.asarray(vt.deg)
    active = np.asarray(vt.del_time) == 0
    bs = g.pool_spec.block_size
    for u in np.nonzero(active)[0]:
        live = max(int(deg[u]), 1)
        assert cap[u] <= 2 * ((live + bs - 1) // bs) * bs + 2 * 128, u
        assert size[u] <= cap[u]


def test_get_neighbors_reads_extent_only(rng):
    """The neighbor query width is the requested cap, independent of n/m."""
    g = RadixGraph(n_max=512, key_bits=16, expected_n=64, batch=64,
                   pool_blocks=4096, block_size=8, dmax=512)
    g.add_edges(np.array([3, 3, 3], np.uint64), np.array([4, 5, 6], np.uint64))
    ids, w = g.neighbors([3], width=64)[0]
    assert set(ids.tolist()) == {4, 5, 6}
