"""Concurrent graph query/update service: sealed-epoch read pinning, mixed
scheduling, distributed analytics answers vs a single-shard reference.
The service drives storage exclusively through ``repro.api.GraphStore``."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analytics as A
from repro.api import make_store
from repro.core.radixgraph import RadixGraph
from repro.serve.graph_service import GraphQueryService


@pytest.fixture(scope="module")
def served():
    rng = np.random.default_rng(7)
    ids = rng.choice(2 ** 32, 90, replace=False).astype(np.uint64)
    n_e = 1500
    src, dst = rng.choice(ids, n_e), rng.choice(ids, n_e)
    w = rng.uniform(0.5, 2, n_e).astype(np.float32)
    w[rng.random(n_e) < 0.15] = 0.0
    store = make_store("sharded", n_shards=1, n_per_shard=2048,
                       expected_n=512, pool_blocks=8192, block_size=8,
                       dmax=512, k_max=64, batch=256, query_batch=64)
    svc = GraphQueryService(store, pr_iters=25)
    svc.submit_update(src, dst, w)
    svc.run()
    oracle = {}
    for s, d, ww in zip(src, dst, w):
        if ww == 0:
            oracle.pop((int(s), int(d)), None)
        else:
            oracle[(int(s), int(d))] = float(ww)
    return svc, ids, src, dst, w, oracle


def test_degree_queries_match_oracle(served):
    svc, ids, src, dst, w, oracle = served
    t = svc.submit_query("degree", ids=ids)
    res = svc.run()
    deg = {}
    for (s, d) in oracle:
        deg[s] = deg.get(s, 0) + 1
    exp = np.array([deg.get(int(x), 0) for x in ids])
    assert np.array_equal(res[t], exp)
    assert svc.stats["ops_dropped"] == 0


def test_reads_pinned_to_sealed_epoch(served):
    svc, ids, src, dst, w, oracle = served
    probe = ids[:8]
    # churn an edge between EXISTING vertices absent from the live edge set,
    # so the fixture graph ends bit-identical for the other tests
    extra_dst = next(int(x) for x in ids[20:]
                     if (int(probe[0]), int(x)) not in oracle)
    t0 = svc.submit_query("degree", ids=probe)
    svc.run()
    sealed_answer = svc.results[t0]
    # enqueue a write plus a read: within the step, the read must answer
    # from the PREVIOUS sealed epoch (the write lands first but is unsealed)
    svc.submit_update(probe[:1], [extra_dst], [1.0])
    t1 = svc.submit_query("degree", ids=probe)
    svc.step()
    assert np.array_equal(svc.results[t1], sealed_answer)
    # after the end-of-step seal, the next read observes the write
    t2 = svc.submit_query("degree", ids=probe)
    svc.run()
    bumped = sealed_answer.copy()
    bumped[0] += 1
    assert np.array_equal(svc.results[t2], bumped)
    # restore for other tests
    svc.submit_update(probe[:1], [extra_dst], [0.0])
    svc.run()


def test_analytics_match_single_shard_reference(served):
    svc, ids, src, dst, w, oracle = served
    tb = svc.submit_query("bfs", source=int(src[0]))
    tp = svc.submit_query("pagerank")
    res = svc.run()

    g = RadixGraph(n_max=512, key_bits=32, expected_n=128, batch=512,
                   pool_blocks=8192, block_size=8, dmax=512, k_max=64)
    g.apply_ops(src, dst, w)
    snap = g.snapshot()
    off = g.lookup(ids)
    s0 = int(g.lookup(np.array([src[0]], np.uint64))[0])
    ref_d = np.asarray(A.bfs(snap, jnp.int32(s0)))
    ref_pr = np.asarray(A.pagerank(snap, iters=25))
    for i, vid in enumerate(ids):
        assert res[tb].get(int(vid), -2) == int(ref_d[int(off[i])])
        assert float(res[tp][int(vid)]) == pytest.approx(
            float(ref_pr[int(off[i])]), abs=1e-6)


def test_analytics_memoized_per_epoch(served):
    svc, ids, src, dst, w, oracle = served
    t1 = svc.submit_query("pagerank")
    t2 = svc.submit_query("pagerank")
    svc.run()
    # both answered within one sealed epoch: the second rides the memo
    assert svc.results[t2] is svc.results[t1]


def test_sync_reused_across_epochs_without_vertex_creation(served):
    svc, ids, src, dst, w, oracle = served
    # analytics on the sealed epoch must NOT recompute the vertex sync:
    # the write path keeps the live state registered incrementally
    runs0 = svc.stats["sync_runs"]
    svc.submit_query("pagerank")
    svc.run()
    reused0 = svc.stats["sync_reused"]
    assert reused0 > 0
    assert svc.stats["sync_runs"] == runs0
    # churn edges between EXISTING vertices: no vertices created, so the
    # per-step incremental sync is skipped entirely (no collective)
    skips0 = svc.stats["sync_skips"]
    svc.submit_update(src[:4], dst[:4], w[:4] + 1.0)
    svc.submit_update(src[:4], dst[:4], w[:4])       # restore weights
    svc.submit_query("pagerank")
    svc.run()
    assert svc.stats["sync_runs"] == runs0
    assert svc.stats["sync_skips"] > skips0
    assert svc.stats["sync_reused"] > reused0
    # writes that CREATE vertices do run the incremental sync
    known = set(int(x) for x in ids)
    fresh = np.array([x for x in range(7, 100) if x not in known][:2],
                     np.uint64)
    svc.submit_update(fresh, fresh[::-1], np.ones(2, np.float32))
    svc.run()
    assert svc.stats["sync_runs"] == runs0 + 1
    # and analytics on the new epoch still answer from the reused sync
    t = svc.submit_query("bfs", source=int(fresh[0]))
    res = svc.run()
    assert res[t][int(fresh[1])] == 1
    # clean up the extra edges for any later test using the fixture
    svc.submit_update(fresh, fresh[::-1], np.zeros(2, np.float32))
    svc.run()


def test_pipelined_write_drain_and_stats_depth_reporting():
    """A depth-K service drains K device batches per flush and ``stats``
    reports the admission picture: queued-vs-inflight write depth plus the
    store's pipeline flush counters (ISSUE 6 satellite)."""
    rng = np.random.default_rng(11)
    ids = rng.choice(2 ** 32, 64, replace=False).astype(np.uint64)
    n_e = 64 * 10          # 10 device batches of 64
    src, dst = rng.choice(ids, n_e), rng.choice(ids, n_e)
    w = rng.uniform(0.5, 2, n_e).astype(np.float32)

    def make(depth):
        return GraphQueryService(
            make_store("sharded", n_shards=1, n_per_shard=1024,
                       expected_n=256, pool_blocks=2048, block_size=8,
                       dmax=256, k_max=32, batch=64, query_batch=32),
            pipeline_depth=depth)

    deep = make(4)
    assert deep.stats["write_flushes"] == 0
    assert deep.stats["queued_write_ops"] == 0
    deep.submit_update(src, dst, w)
    assert deep.stats["queued_write_ops"] == n_e
    deep.step()            # one flush ships pipeline_depth * batch ops
    assert deep.stats["write_flushes"] == 1
    assert deep.stats["inflight_write_batches"] == 4
    assert deep.stats["queued_write_ops"] == n_e - 4 * 64
    # the store-side pipeline counters surface through the merged stats
    assert deep.stats["flushes"] == 1
    assert deep.stats["super_batches"] == 1     # 4 batches, one scan program
    deep.run()
    assert deep.stats["queued_write_ops"] == 0
    # 10 batches at depth 4 -> flush groups [4, 4, 2]; the ragged tail
    # reports its true (smaller) inflight depth
    assert deep.stats["write_flushes"] == 3
    assert deep.stats["inflight_write_batches"] == 2

    # parity: the deep pipeline answers exactly like the classic depth-1
    # scheduling (which needs one flush per device batch)
    flat = make(1)
    flat.submit_update(src, dst, w)
    flat.run()
    assert flat.stats["write_flushes"] == 10
    assert flat.stats["super_batches"] == 10
    td, tf = (s.submit_query("degree", ids=ids) for s in (deep, flat))
    assert np.array_equal(deep.run()[td], flat.run()[tf])
    assert deep.stats["ops_dropped"] == flat.stats["ops_dropped"] == 0


def test_backpressure():
    svc = GraphQueryService(
        make_store("sharded", n_shards=1, n_per_shard=512, expected_n=128,
                   pool_blocks=1024, block_size=8, dmax=128, k_max=32,
                   batch=64, query_batch=32),
        max_pending=100)
    ok = svc.submit_update(np.arange(90, dtype=np.uint64),
                           np.arange(90, dtype=np.uint64) + 1)
    assert ok
    assert not svc.submit_update(np.arange(20, dtype=np.uint64),
                                 np.arange(20, dtype=np.uint64) + 1)
    svc.run()
    assert svc.submit_update(np.arange(20, dtype=np.uint64),
                             np.arange(20, dtype=np.uint64) + 1)
