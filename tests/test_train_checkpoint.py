"""Training loop + fault tolerance: loss decreases, exact resume, atomic
saves, GC, async, elastic restore."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (Checkpointer, latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_arch
from repro.data import TokenStream
from repro.launch import train as train_mod
from repro.models.api import build_model
from repro.train import adamw, cosine_schedule, init_train_state, \
    make_train_step


def test_loss_decreases(tmp_path):
    losses = train_mod.main(["--arch", "internlm2-1.8b", "--smoke",
                             "--steps", "120", "--batch", "16",
                             "--seq", "64", "--lr", "1e-3"])
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.05


def test_checkpoint_exact_resume(tmp_path):
    d = str(tmp_path / "ck")
    common = ["--arch", "internlm2-1.8b", "--smoke", "--batch", "4",
              "--seq", "32", "--schedule-total", "30"]
    a = train_mod.main(common + ["--steps", "20", "--ckpt-dir", d,
                                 "--ckpt-every", "10"])
    b = train_mod.main(common + ["--steps", "30", "--ckpt-dir", d,
                                 "--ckpt-every", "10"])
    c = train_mod.main(common + ["--steps", "30"])
    # resumed steps 20..29 equal the uninterrupted run's steps 20..29
    np.testing.assert_allclose(b[-5:], c[-5:], rtol=2e-4, atol=1e-5)


def test_checkpoint_atomicity_and_gc(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, tree, s, {"x": s}, keep=2)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_") and
                   not p.name.endswith(".tmp"))
    assert steps == [4, 5]
    got, step, meta = restore_checkpoint(tmp_path, tree)
    assert step == 5 and meta["x"] == 5
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10.0))


def test_checkpoint_async_and_elastic(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"w": jnp.ones((8, 4)) * 3}
    ck.save_async(tree, 7, {"stream": {"step": 1, "seed": 0}})
    ck.wait()
    assert latest_step(tmp_path) == 7
    # elastic: restore onto the current (1-device) topology with an explicit
    # sharding — the save/restore path goes through full logical arrays
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    got, step, _ = restore_checkpoint(tmp_path, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_stream_determinism():
    s1 = TokenStream(128, 4, 16, seed=3)
    a = [next(s1) for _ in range(3)]
    s2 = TokenStream(128, 4, 16, seed=3)
    s2.restore({"step": 2, "seed": 3})
    b = next(s2)
    np.testing.assert_array_equal(a[2]["tokens"], b["tokens"])
