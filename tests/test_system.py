"""End-to-end system behaviour: the paper's full workflow — ingest a dynamic
graph, query it, run analytics on MVCC snapshots, keep ingesting, feed an LM
from graph walks — plus the paper's headline properties."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analytics as A
from repro.core.radixgraph import RadixGraph
from repro.data import GraphWalkStream


@pytest.fixture(scope="module")
def live_graph():
    rng = np.random.default_rng(42)
    g = RadixGraph(n_max=2048, key_bits=32, expected_n=512, batch=1024,
                   pool_blocks=16384, block_size=8, dmax=2048,
                   undirected=True)
    ids = rng.choice(2 ** 32, 400, replace=False).astype(np.uint64)
    oracle = {}
    versions = []
    for wave in range(5):
        src = rng.choice(ids, 800)
        dst = rng.choice(ids, 800)
        w = rng.uniform(0.5, 2, 800).astype(np.float32)
        w[rng.random(800) < 0.2] = 0.0
        g.apply_ops(src, dst, w)
        for s, d, ww in zip(src, dst, w):
            for a, b in ((int(s), int(d)), (int(d), int(s))):
                if ww == 0:
                    oracle.pop((a, b), None)
                else:
                    oracle[(a, b)] = float(ww)
        versions.append((g.checkpoint_version(), len(oracle), g.state))
    return g, ids, oracle, versions


def test_streaming_ingest_counts(live_graph):
    g, ids, oracle, versions = live_graph
    assert g.num_edges == len(oracle)
    assert not g.overflowed


def test_mvcc_versions_answer_historically(live_graph):
    g, ids, oracle, versions = live_graph
    for ts, m, state in versions:
        old = RadixGraph.__new__(RadixGraph)
        old.__dict__.update(g.__dict__)
        old.state = state
        assert old.num_edges == m


def test_analytics_on_live_graph(live_graph):
    g, ids, oracle, versions = live_graph
    snap = g.snapshot()
    off = g.lookup(ids)
    ok = off >= 0
    pr = np.asarray(A.pagerank(snap, iters=10))
    assert pr[off[ok]].sum() == pytest.approx(1.0, abs=1e-3)
    lab = np.asarray(A.wcc(snap))
    assert (lab[off[ok]] >= 0).all()
    depth = np.asarray(A.bfs(snap, jnp.int32(int(off[ok][0]))))
    assert depth[int(off[ok][0])] == 0


def test_graph_feeds_lm_pipeline(live_graph):
    g, ids, oracle, versions = live_graph
    stream = GraphWalkStream(g, vocab=128, batch=4, seq=16)
    b = next(stream)
    assert b["tokens"].shape == (4, 16)
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 128).all()
    assert stream.indptr[-1] > 0


def test_edge_chain_roundtrip(live_graph):
    """Edge blocks store OFFSETS (the chain): neighbors' offsets resolve to
    the same rows the IDs resolve to (Fig. 6 semantics)."""
    g, ids, oracle, versions = live_graph
    out = g.neighbors(ids[:4].tolist(), as_ids=False)
    out_ids = g.neighbors(ids[:4].tolist(), as_ids=True)
    vt_ids = np.asarray(g.state.vt.ids)
    for (offs, _), (nids, _) in zip(out, out_ids):
        hi = vt_ids[offs, 0].astype(np.uint64) << np.uint64(32)
        assert np.array_equal(hi | vt_ids[offs, 1].astype(np.uint64), nids)


def test_memory_is_linear_in_edges(live_graph):
    g, ids, oracle, versions = live_graph
    m = g.num_edges
    mem = g.memory_bytes()
    # O(m): 12 B/entry x 2x capacity + vertex rows + SORT materialization
    assert mem < 12 * 2 * (2 * m) + 64 * 1000 + 4 * 10 ** 6
