"""Paper Fig. 13: SORT vs vEB memory under uniform / skewed / heavy-tailed
ID workloads."""
from __future__ import annotations

import numpy as np

from repro.core import sort as sort_mod
from repro.core.keys import pack_keys
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import optimize_sort, veb_config

from .common import emit

import jax.numpy as jnp


def _workload(kind: str, n: int, rng):
    if kind == "uniform":
        return rng.choice(2 ** 32, n, replace=False).astype(np.uint64)
    if kind == "skewed":
        return rng.choice(int((2 ** 32 - 1) / 1.5), n,
                          replace=False).astype(np.uint64)
    # heavy-tailed: reciprocal distribution p(i) ~ 1/i
    u = rng.random(n * 3)
    ids = np.unique((np.exp(u * np.log(2 ** 32)) - 1).astype(np.uint64))
    rng.shuffle(ids)
    return ids[:n]


def run(scale: float = 1.0):
    rows = [("fig13", "workload", "structure", "n", "materialized_slots",
             "memory_kb")]
    rng = np.random.default_rng(0)
    n = int(100_000 * scale)
    for kind in ("uniform", "skewed", "heavy-tailed"):
        ids = _workload(kind, n, rng)
        nn = len(ids)
        for name, cfg in (("sort", optimize_sort(nn, 32, 5)),
                          ("veb", veb_config(nn, 32))):
            spec = SortSpec.from_config(cfg, nn + 8)
            st = sort_mod.make_sort(spec)
            st = sort_mod.insert_mappings(
                spec, st, pack_keys(ids, 32),
                jnp.arange(nn, dtype=jnp.int32), jnp.ones(nn, bool))
            slots = int(sort_mod.materialized_slots(spec, st))
            rows.append(("fig13", kind, name, nn, slots, slots * 4 // 1024))
    return emit(rows)


if __name__ == "__main__":
    run()
