import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis per (arch x shape) cell — TPU v5e target.

Methodology (CPU container, no wall clocks):
  * compute & memory terms: the model is re-lowered with UNROLLED layers on a
    reduced (4, 4) mesh — XLA cost_analysis is exact for straight-line HLO
    (while bodies are otherwise counted once) — and totals scale as
    per-device x 16. Terms are then evaluated for the production 256-chip
    pod.
  * collective term: per-device collective bytes from the production-mesh
    dry-run HLO (trip-count-aware parser, launch/hlo.py) — exact at 256-way
    sharding.
  * MODEL_FLOPS = 6·N·D for train cells (N = active params for MoE),
    2·N·D for prefill, 2·N per token for decode; the ratio
    MODEL_FLOPS / HLO_FLOPS exposes remat/dispatch/attention overheads.

Hardware constants (v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m benchmarks.roofline --all
  (reads benchmarks/results/dryrun/*.json; missing dry-runs are run inline)
"""
import argparse
import dataclasses
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_arch
from repro.dist.sharding import (TRAIN_RULES, SERVE_RULES, MOE_SERVE_RULES,
                                 param_partition_specs, set_rules, spec_for)
from repro.models.api import (build_model, cache_specs, input_specs,
                              param_counts, shapes_and_logical)
from repro.train import adamw, adafactor, cosine_schedule, make_train_step
from repro.train.step import TrainState

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link
CHIPS = 256              # single-pod roofline target

HERE = pathlib.Path(__file__).resolve().parent
DRYRUN = HERE / "results" / "dryrun"
OUT = HERE / "results" / "roofline"


def small_mesh():
    return jax.make_mesh((4, 4), ("data", "model"),
                         devices=jax.devices()[:16],
                         axis_types=(AxisType.Auto, AxisType.Auto))


def lower_unrolled(arch: str, shape: str, variant: str = "baseline"):
    """Exact unrolled cost via layer extrapolation.

    XLA cost_analysis is exact on straight-line HLO; unrolling the FULL depth
    is too slow to compile, but cost is affine in depth:
        cost(L) = base + L * per_layer
    so two unrolled lowerings at small depths (k, 2k) recover base/per_layer
    exactly and extrapolate to the real depth (3 points for hybrid's
    units+tail structure). Returns (flops_total, bytes_total) for the cell.
    """
    from repro.dist.sharding import VARIANTS
    _, cfg_over = VARIANTS[variant]
    mod = get_arch(arch)
    cfg0 = dataclasses.replace(mod.CONFIG, **cfg_over)
    if cfg0.family == "hybrid":
        unit = len(cfg0.pattern)
        Lfull = cfg0.layers
        groups = Lfull // unit
        tail = Lfull - groups * unit
        c1 = _cell_cost(dataclasses.replace(cfg0, layers=unit), arch, shape, variant)
        c2 = _cell_cost(dataclasses.replace(cfg0, layers=2 * unit), arch,
                        shape, variant)
        per_unit = (np.array(c2) - np.array(c1))
        base = np.array(c1) - per_unit
        total = base + groups * per_unit
        if tail:
            c3 = _cell_cost(dataclasses.replace(cfg0, layers=unit + tail),
                            arch, shape, variant)
            per_tail = (np.array(c3) - np.array(c1)) / tail
            total = total + tail * per_tail
        return float(total[0]), float(total[1])
    if cfg0.family == "encdec":
        c1 = _cell_cost(dataclasses.replace(cfg0, layers=2, enc_layers=2,
                                            dec_layers=2), arch, shape,
                        variant)
        c2 = _cell_cost(dataclasses.replace(cfg0, layers=4, enc_layers=4,
                                            dec_layers=4), arch, shape,
                        variant)
        per = (np.array(c2) - np.array(c1)) / 2
        base = np.array(c1) - 2 * per
        total = base + cfg0.layers * per
        return float(total[0]), float(total[1])
    c1 = _cell_cost(dataclasses.replace(cfg0, layers=2), arch, shape, variant)
    c2 = _cell_cost(dataclasses.replace(cfg0, layers=4), arch, shape, variant)
    per = (np.array(c2) - np.array(c1)) / 2
    base = np.array(c1) - 2 * per
    total = base + cfg0.layers * per
    return float(total[0]), float(total[1])


def _cell_cost(cfg, arch: str, shape: str, variant: str = 'baseline'):
    """cost_analysis (flops, bytes) totals for one unrolled lowering.

    Attention/loss chunk sizes are set to the full sequence so the flash /
    xent lax.scans disappear (straight-line HLO -> exact flop counts; the
    scan implementation computes the same block flops, incl. masked causal
    waste). Bytes from this lowering are an unfused upper bound (reported,
    not the memory term)."""
    kind, seq, batch = SHAPES[shape]
    from repro.dist.sharding import VARIANTS, ShardingRules
    rule_over, _ = VARIANTS[variant]
    cfg = dataclasses.replace(cfg, unroll_layers=True, q_chunk=seq,
                              kv_chunk=seq, loss_chunk=seq)
    mesh = small_mesh()
    model = build_model(cfg)
    pshapes, logical = shapes_and_logical(cfg)
    big_moe = cfg.family == "moe"
    mod = get_arch(arch)  # noqa: F841 (kept for parity with run_cell)
    rules = TRAIN_RULES if kind == "train" else (
        MOE_SERVE_RULES if big_moe else SERVE_RULES)
    rules = ShardingRules({**rules, **rule_over})
    pspecs = param_partition_specs(pshapes, logical, rules, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    repl = NamedSharding(mesh, P())
    specs = input_specs(cfg, kind, seq, batch)
    batch_sh = {k: repl for k in specs}
    batch_sh["tokens" if kind != "decode" else "token"] = NamedSharding(
        mesh, spec_for(specs["tokens" if kind != "decode" else "token"].shape,
                       ("batch",) + (None,) * (len(specs[
                           "tokens" if kind != "decode" else "token"].shape) - 1),
                       rules, mesh))
    if "labels" in specs:
        batch_sh["labels"] = batch_sh["tokens"]

    with set_rules(rules, mesh):
        if kind == "train":
            opt = adafactor(cosine_schedule(1e-4, 100, 10000)) if big_moe \
                else adamw(cosine_schedule(3e-4, 100, 10000))
            step_fn = make_train_step(model, opt)
            ost = jax.eval_shape(opt.init, pshapes)
            state_struct = TrainState(params=pshapes, opt_state=ost,
                                      step=jax.ShapeDtypeStruct((), jnp.int32))
            fn = jax.jit(step_fn, in_shardings=(
                TrainState(params=psh,
                           opt_state=jax.tree.map(lambda _: repl, ost),
                           step=repl), batch_sh), donate_argnums=(0,))
            compiled = fn.lower(state_struct, specs).compile()
        else:
            cspec = cache_specs(cfg, batch, seq)
            csh = jax.tree.map(lambda _: repl, cspec)
            entry = model.prefill if kind == "prefill" else model.decode
            fn = jax.jit(entry, in_shardings=(psh, batch_sh, csh),
                         donate_argnums=(2,))
            compiled = fn.lower(pshapes, specs, cspec).compile()
    from repro.launch.hlo import cost_dict
    cost = cost_dict(compiled)
    return float(cost.get("flops", 0.0)) * 16, float(cost.get("bytes accessed", 0.0)) * 16


def analytic_bytes(arch: str, shape: str) -> float:
    """Transparent HBM-traffic model (bytes, whole cell) — the memory term.

    XLA's bytes-accessed is a ~5x unfused upper bound (see EXPERIMENTS.md
    calibration), so the roofline memory term uses explicit napkin math:

    train:  params: read fwd + read recompute (remat) + read bwd + write grad
            (f32) + optimizer state r/w; activations: residual-stream and
            ffn tiles r/w twice (fwd+bwd) in bf16 with remat re-reads;
            attention q/k/v/o streams; loss logits streamed chunked.
    prefill: params read once per token-block; activations fwd only; cache
            written once.
    decode: active params read once; KV/state cache read once, one slot
            written; activations negligible.
    """
    mod = get_arch(arch)
    cfg = mod.CONFIG
    kind, seq, batch = SHAPES[shape]
    tot, act = param_counts(cfg)
    pb = 2 if cfg.param_dtype == "bfloat16" else 4
    tokens = seq * batch
    d, f, L, V = cfg.d_model, cfg.d_ff, cfg.layers, cfg.vocab
    hd, Hq, Hkv = cfg.hd, max(cfg.n_heads, 1), max(cfg.kv_heads, 1)

    if cfg.family == "moe":
        f_active = f * cfg.top_k * cfg.capacity_factor
    elif cfg.family == "ssm":
        f_active = cfg.ssm_expand * d * 2
    else:
        f_active = f

    # per-token per-layer activation values touched (r+w, fwd), bf16
    act_vals = 6 * d + 4 * f_active + 4 * Hq * hd
    if kind == "train":
        opt_b = 20 * tot if cfg.family != "moe" else 6 * tot  # adamw vs adafactor
        params_b = tot * pb * 3 + tot * 4 + opt_b
        acts_b = tokens * L * act_vals * 2 * 2.5   # fwd + bwd + remat reread
        loss_b = 2 * tokens * V * 4 / max(1, seq // cfg.loss_chunk) + \
            2 * tokens * d * 4
        return params_b + acts_b + loss_b
    if kind == "prefill":
        cache_b = tokens * L * 2 * Hkv * hd * 2
        return act * pb + tokens * L * act_vals * 2 + cache_b
    # decode
    if cfg.family == "ssm":
        din = cfg.ssm_expand * d
        H = cfg.ssm_heads or (din // cfg.ssm_head_dim)
        Pd = din // H
        cache_b = L * batch * H * Pd * cfg.ssm_state * 4 * 2
    elif cfg.family == "hybrid":
        Dr = cfg.lru_width or d
        W = min(seq, cfg.window or seq)
        n_att = L // 3
        cache_b = L * batch * Dr * 4 * 2 + \
            n_att * batch * W * 2 * Hkv * hd * 2
    else:
        W = min(seq, cfg.window or seq)
        cache_b = L * batch * W * 2 * Hkv * hd * 2
    if cfg.family == "moe":
        touched = min(cfg.n_experts, batch * cfg.top_k) / cfg.n_experts
        expert_p = tot - act  # ~ inactive mass scales with expert params
        moe_b = (act + touched * expert_p) * pb
        return moe_b + cache_b
    return act * pb + cache_b


def model_flops(arch: str, shape: str) -> float:
    cfg = get_arch(arch).CONFIG
    kind, seq, batch = SHAPES[shape]
    _, act = param_counts(cfg)
    if kind == "train":
        return 6.0 * act * seq * batch
    if kind == "prefill":
        return 2.0 * act * seq * batch
    return 2.0 * act * batch          # decode: one token per sequence


def analyze(arch: str, shape: str, force: bool = False,
            variant: str = "baseline"):
    mod = get_arch(arch)
    skip = getattr(mod, "SKIPS", {}).get(shape)
    if skip:
        rec = {"arch": arch, "shape": shape, "status": "skip", "reason": skip}
        _save(rec, variant)
        return rec
    suffix = "single" if variant == "baseline" else f"single+{variant}"
    dj = DRYRUN / f"{arch}__{shape}__{suffix}.json"
    if not dj.exists():
        from repro.launch.dryrun import run_cell
        run_cell(arch, shape, multi_pod=False, variant=variant)
    dr = json.loads(dj.read_text())
    if dr.get("status") != "ok":
        rec = {"arch": arch, "shape": shape, "status": "blocked-by-dryrun",
               "dryrun": dr.get("error", dr.get("status"))}
        _save(rec)
        return rec

    vs = "" if variant == "baseline" else f"__{variant}"
    out = OUT / f"{arch}__{shape}{vs}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())

    flops_total, bytes_ub_total = lower_unrolled(arch, shape, variant)
    bytes_total = analytic_bytes(arch, shape)
    coll_per_dev = sum(dr["collective_bytes"].values())

    t_compute = flops_total / (CHIPS * PEAK_FLOPS)
    t_memory = bytes_total / (CHIPS * HBM_BW)
    t_coll = coll_per_dev / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    bound = max(terms.values())
    rec = {
        "arch": arch, "shape": shape, "status": "ok", "chips": CHIPS,
        "variant": variant, "kind": dr["kind"],
        "hlo_flops_total": flops_total,
        "analytic_bytes_total": bytes_total,
        "xla_bytes_unfused_ub": bytes_ub_total,
        "collective_bytes_per_dev": coll_per_dev,
        "collective_breakdown": dr["collective_bytes"],
        **{k: float(v) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(flops_total, 1.0),
        "roofline_fraction": (mf / PEAK_FLOPS / CHIPS) / max(bound, 1e-30),
        "memory_per_dev": dr.get("memory", {}),
        "lever": _lever(dominant),
    }
    _save(rec, variant)
    return rec


def _lever(dominant: str) -> str:
    return {
        "compute_s": "raise useful-flops ratio: relax remat policy on cheap "
                     "ops, cut attention-mask waste (block-causal skip), or "
                     "reduce MoE over-capacity compute",
        "memory_s": "cut HBM traffic: fuse norm/rope into matmul epilogues, "
                    "keep bf16 end-to-end, shrink optimizer state touches "
                    "(factored stats), larger microbatch per step",
        "collective_s": "re-shard to kill gathers: move FSDP gathers out of "
                        "the remat region, shard activations on the axis the "
                        "dominant gather targets, overlap collectives with "
                        "compute (latency-hiding scheduler), or compress "
                        "gradients (int8 allreduce)",
    }[dominant]


def _save(rec, variant: str = "baseline"):
    OUT.mkdir(parents=True, exist_ok=True)
    vs = "" if variant == "baseline" else f"__{variant}"
    (OUT / f"{rec['arch']}__{rec['shape']}{vs}.json").write_text(
        json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    cells = [(args.arch, args.shape)] if not args.all else \
        [(a, s) for a in ARCH_IDS for s in SHAPES]
    rows = []
    for a, s in cells:
        try:
            r = analyze(a, s, force=args.force, variant=args.variant)
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            r = {"arch": a, "shape": s, "status": "fail", "error": str(e)[:300]}
            _save(r)
        rows.append(r)
        if r.get("status") == "ok":
            print(f"{a:26s} {s:12s} C={r['compute_s']:.3f}s "
                  f"M={r['memory_s']:.3f}s X={r['collective_s']:.3f}s "
                  f"dom={r['dominant'][:-2]:10s} "
                  f"useful={r['useful_flops_ratio']:.2f} "
                  f"roofline={r['roofline_fraction']:.3f}")
        else:
            print(f"{a:26s} {s:12s} {r['status']}")


if __name__ == "__main__":
    main()
