"""Shared benchmark utilities: timing, synthetic datasets (paper Table 4 at
CPU scale), CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import jax
import numpy as np

U32 = 2 ** 32


def timeit(fn: Callable, *args, iters: int = 3, warmup: int = 1, **kw):
    """Median wall time (s) with block_until_ready on pytree outputs."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def edge_stream(n_vertices: int, n_edges: int, dist: str = "powerlaw",
                seed: int = 0, id_bits: int = 32):
    """(src, dst, vertex_ids): non-contiguous IDs, paper-style topology."""
    rng = np.random.default_rng(seed)
    ids = rng.choice(2 ** id_bits, size=n_vertices, replace=False).astype(
        np.uint64)
    if dist == "powerlaw":
        # zipf-ish endpoint selection (g500-like skew)
        p = 1.0 / np.arange(1, n_vertices + 1) ** 0.8
        p /= p.sum()
        src = ids[rng.choice(n_vertices, n_edges, p=p)]
        dst = ids[rng.choice(n_vertices, n_edges, p=p)]
    else:
        src = ids[rng.integers(0, n_vertices, n_edges)]
        dst = ids[rng.integers(0, n_vertices, n_edges)]
    return src, dst, ids


# scaled-down Table 4 (container CPU scale; --scale grows them on hardware)
DATASETS: Dict[str, Tuple[int, int, str]] = {
    "lj": (4000, 32000, "powerlaw"),       # livejournal-like
    "dota": (600, 48000, "uniform"),       # dense (avg deg ~80)
    "orkut": (3000, 110000, "powerlaw"),
    "g24": (9000, 96000, "powerlaw"),
    "u24": (9000, 96000, "uniform"),
    "twitter": (16000, 200000, "powerlaw"),
}


def dataset(name: str, scale: float = 1.0, seed: int = 0):
    n, m, dist = DATASETS[name]
    return edge_stream(int(n * scale), int(m * scale), dist, seed)


# fixed static capacities shared by every graph benchmark — one jit cache
# across datasets/policies (different capacities would recompile everything)
GRAPH_CAPS = dict(n_max=40960, pool_blocks=131072, block_size=16,
                  dmax=4096, k_max=256, batch=4096)


def make_graph(policy: str = "snaplog", expected_n: int = 8192, **over):
    from repro.core.radixgraph import RadixGraph
    kw = dict(GRAPH_CAPS)
    kw.update(over)
    return RadixGraph(key_bits=32, expected_n=expected_n, policy=policy,
                      undirected=True, **kw)


def emit(rows):
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
