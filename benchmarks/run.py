"""Benchmark driver — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale 1.0] [--only fig8,table5]

Prints CSV rows (``table,...,value`` per line). Roofline/dry-run artifacts
are separate (benchmarks.roofline, repro.launch.dryrun) since they need the
512-placeholder-device environment.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25,
                    help="dataset scale factor (1.0 = Table-4-mini sizes)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig8,table5")
    args = ap.parse_args(argv)

    from . import (fig8_ops, fig9_mixed, fig10_analytics, fig11_concurrent,
                   fig12_sort_case, fig13_workloads, table2_radix_structures,
                   table5_sort_vs_art, table6_ablation, table7_batch)

    suites = {
        "table2": table2_radix_structures.run,
        "fig8": fig8_ops.run,
        "fig9": fig9_mixed.run,
        "fig10": fig10_analytics.run,
        "fig11": fig11_concurrent.run,
        "fig12": fig12_sort_case.run,
        "fig13": fig13_workloads.run,
        "table5": table5_sort_vs_art.run,
        "table6": table6_ablation.run,
        "table7": table7_batch.run,
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    failed = []
    for name, fn in suites.items():
        if name not in only:
            continue
        t0 = time.time()
        print(f"# ==== {name} (scale={args.scale}) ====")
        try:
            fn(scale=args.scale)
        except Exception:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            failed.append(name)
        print(f"# {name} done in {time.time() - t0:.1f}s")
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
