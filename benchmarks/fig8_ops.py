"""Paper Fig. 8: edge insert/delete throughput, vertex insert/query
throughput, and memory across datasets — RadixGraph (snaplog) vs the
log-structured ('grow', LiveGraph-paradigm) and sorted+buffer ('sorted',
Spruce-paradigm) edge baselines, plus ART/hash vertex-index baselines."""
from __future__ import annotations

import numpy as np

from repro.baselines import HashIndex, JaxART
from repro.core.radixgraph import RadixGraph

from .common import DATASETS, dataset, emit, timeit


def _mk(policy, n, m):
    from .common import make_graph
    return make_graph(policy)


def _warm():
    """Compile-warm the shared jit cache so timings measure execution."""
    from .common import make_graph
    import numpy as np
    rng = np.random.default_rng(9)
    for policy in ("snaplog", "grow", "sorted"):
        g = make_graph(policy)
        s = rng.choice(2 ** 32, 4096).astype(np.uint64)
        g.add_edges(s, s[::-1])
        g.delete_edges(s[:16], s[::-1][:16])
        g.lookup(s[:16])
        g.add_vertices(s[:16])


def run(scale: float = 1.0, datasets=("lj", "dota", "u24")):
    rows = [("fig8", "dataset", "system", "edge_ins_Mops", "edge_del_Mops",
             "vtx_ins_Mops", "vtx_qry_Mops", "memory_mb")]
    _warm()
    for ds in datasets:
        src, dst, ids = dataset(ds, scale)
        n, m = len(ids), len(src)
        half = m // 2
        for policy in ("snaplog", "grow", "sorted"):
            g = _mk(policy, n, m)
            t_ins, _ = timeit(lambda: g.add_edges(src, dst), iters=1,
                              warmup=0)
            t_del, _ = timeit(lambda: g.delete_edges(src[:half], dst[:half]),
                              iters=1, warmup=0)
            mem = g.memory_bytes() / 2 ** 20
            name = {"snaplog": "RadixGraph", "grow": "log-store",
                    "sorted": "sorted+buffer"}[policy]
            rows.append(("fig8", ds, name, round(2 * m / t_ins / 1e6, 3),
                         round(2 * half / t_del / 1e6, 3), "", "",
                         round(mem, 2)))
        # vertex index microbench (insert + query) on this ID set
        qs = np.concatenate([ids, ids[: max(1, n // 2)]])
        g = _mk("snaplog", n, m)
        from .common import make_graph
        t_vi, _ = timeit(lambda: make_graph("snaplog").add_vertices(ids),
                         iters=1, warmup=0)
        t_vq, _ = timeit(lambda: g.lookup(qs), iters=2, warmup=1)
        rows.append(("fig8", ds, "RadixGraph-vertex", "", "",
                     round(n / t_vi / 1e6, 3), round(len(qs) / t_vq / 1e6, 3),
                     ""))
        art = JaxART(n_max=8192)
        t_ai, _ = timeit(lambda: art.insert(ids, np.arange(n, dtype=np.int32)),
                         iters=1, warmup=0)
        t_aq, _ = timeit(lambda: art.lookup(qs), iters=2, warmup=1)
        rows.append(("fig8", ds, "ART-vertex", "", "",
                     round(n / t_ai / 1e6, 4), round(len(qs) / t_aq / 1e6, 3),
                     round(art.memory_bytes() / 2 ** 20, 3)))
        h = HashIndex(n_max=8192)
        t_hi, _ = timeit(lambda: h.insert(ids, np.arange(n, dtype=np.int32)),
                         iters=1, warmup=0)
        t_hq, _ = timeit(lambda: h.lookup(qs), iters=2, warmup=1)
        rows.append(("fig8", ds, "hash-vertex", "", "",
                     round(n / t_hi / 1e6, 3), round(len(qs) / t_hq / 1e6, 3),
                     round(h.memory_bytes() / 2 ** 20, 3)))
    return emit(rows)


if __name__ == "__main__":
    run()
