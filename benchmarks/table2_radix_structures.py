"""Paper Table 2: uniform-tree vs vEB-tree vs SORT — memory & insertion time
for n random IDs in [0, 2^32). Same layer budget l = lglg(u) = 5."""
from __future__ import annotations

import numpy as np

from repro.core import sort as sort_mod
from repro.core.keys import pack_keys
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import optimize_sort, uniform_config, veb_config

from .common import timeit, emit


def _insert_all(spec, ids):
    st = sort_mod.make_sort(spec)
    keys = pack_keys(ids, 32)
    offs = np.arange(len(ids), dtype=np.int32)
    import jax.numpy as jnp
    st = sort_mod.insert_mappings(spec, st, keys, jnp.asarray(offs),
                                  jnp.ones(len(ids), bool))
    return st


def run(scale: float = 1.0):
    rows = [("table2", "structure", "n", "materialized_slots", "memory_kb",
             "insert_ms")]
    rng = np.random.default_rng(0)
    for n in (int(1e3 * scale), int(1e4 * scale), int(3e4 * scale)):
        ids = rng.choice(2 ** 32, n, replace=False).astype(np.uint64)
        for name, cfg in (
            ("uniform", uniform_config(n, 32, 5)),
            ("veb", veb_config(n, 32)),
            ("sort", optimize_sort(n, 32, 5)),
        ):
            spec = SortSpec.from_config(cfg, n)
            dt, st = timeit(_insert_all, spec, ids, iters=3)
            slots = int(sort_mod.materialized_slots(spec, st))
            rows.append(("table2", name, n, slots, slots * 4 // 1024,
                         round(dt * 1e3, 2)))
    return emit(rows)


if __name__ == "__main__":
    run()
