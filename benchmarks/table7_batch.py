"""Paper Table 7: insert/delete throughput across batch sizes + memory."""
from __future__ import annotations

import numpy as np

from repro.core.radixgraph import RadixGraph

from .common import dataset, emit, timeit


def run(scale: float = 1.0, datasets=("lj", "orkut")):
    rows = [("table7", "dataset", "batch", "insert_ops_s", "delete_ops_s",
             "memory_mb")]
    for ds in datasets[:1 if scale < 0.5 else 2]:
        src, dst, ids = dataset(ds, scale)
        m = len(src)
        for batch in (64, 512, 4096):
            from .common import make_graph
            g = make_graph("snaplog", batch=batch)
            t_i, _ = timeit(lambda: g.add_edges(src, dst), iters=1, warmup=0)
            t_d, _ = timeit(lambda: g.delete_edges(src, dst), iters=1,
                            warmup=0)
            rows.append(("table7", ds, batch, int(2 * m / t_i),
                         int(2 * m / t_d),
                         round(g.memory_bytes() / 2 ** 20, 2)))
    return emit(rows)


if __name__ == "__main__":
    run()
