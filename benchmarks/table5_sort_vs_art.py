"""Paper Table 5: SORT vs ART — insert/query throughput and memory across
(n, u) grid."""
from __future__ import annotations

import numpy as np

from repro.baselines import JaxART
from repro.core import sort as sort_mod
from repro.core.keys import pack_keys
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import optimize_sort

from .common import emit, timeit

import jax.numpy as jnp


def run(scale: float = 1.0):
    rows = [("table5", "n", "u_bits", "structure", "insert_ops_s",
             "query_ops_s", "memory_kb")]
    rng = np.random.default_rng(0)
    for n in (int(1e4 * scale), int(5e4 * scale)):
        for xb in (24, 32):
            ids = rng.choice(2 ** xb, n, replace=False).astype(np.uint64)
            qs = np.concatenate([ids, rng.choice(2 ** xb, n).astype(np.uint64)])
            offs = jnp.arange(n, dtype=jnp.int32)
            keys = pack_keys(ids, xb)
            qkeys = pack_keys(qs, xb)
            cfg = optimize_sort(n, xb, 5)
            spec = SortSpec.from_config(cfg, n + 8)

            def s_ins():
                st = sort_mod.make_sort(spec)
                return sort_mod.insert_mappings(spec, st, keys, offs,
                                                jnp.ones(n, bool))
            t_i, st = timeit(s_ins, iters=2)
            t_q, _ = timeit(lambda: sort_mod.lookup(spec, st, qkeys), iters=3)
            slots = int(sort_mod.materialized_slots(spec, st))
            rows.append(("table5", n, xb, "sort", int(n / t_i),
                         int(len(qs) / t_q), slots * 4 // 1024))

            art = JaxART(n_max=n + 8, key_bits=xb)
            t_i, _ = timeit(lambda: art.insert(ids, np.arange(n, dtype=np.int32)),
                            iters=1, warmup=1)
            t_q, _ = timeit(lambda: art.lookup(qs), iters=3)
            rows.append(("table5", n, xb, "art", int(n / t_i),
                         int(len(qs) / t_q), art.memory_bytes() // 1024))
    return emit(rows)


if __name__ == "__main__":
    run()
