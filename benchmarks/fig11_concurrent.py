"""Paper Fig. 11: concurrent reads & writes.

Thread-scaling becomes shard-scaling on the SPMD substrate: the distributed
graph engine partitions the vertex space over N placeholder devices; writer
throughput = batched edge ops routed via all_to_all, reader throughput =
degree/1-hop queries answered by owners, interleaved 1:1 (the paper's mixed
workload). Run under XLA_FLAGS=--xla_force_host_platform_device_count=8 for
the multi-shard points (benchmarks.run sets 8 by default via a subprocess).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.core import edgepool as ep
from repro.core.keys import pack_keys
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import optimize_sort
from repro.dist.graph_engine import (make_apply_edges, make_khop_counts,
                                     make_sharded_state)

from .common import emit, timeit


def run(scale: float = 1.0):
    rows = [("fig11", "shards", "write_Mops", "read_Mqps")]
    n_dev = len(jax.devices())
    for shards in sorted({1, 2, 4, 8} & set(range(1, n_dev + 1))):
        mesh = jax.make_mesh((shards,), ("data",),
                             devices=jax.devices()[:shards],
                             axis_types=(AxisType.Auto,))
        cfg = optimize_sort(4096, 32, 5)
        sspec = SortSpec.from_config(cfg, 8192)
        pspec = ep.PoolSpec(n_blocks=int(16384 * scale), block_size=16,
                            k_max=128, dmax=2048)
        state = make_sharded_state(sspec, pspec, shards, 8192)
        apply_fn = jax.jit(make_apply_edges(sspec, pspec, mesh, "data"))
        khop = jax.jit(make_khop_counts(sspec, pspec, mesh, "data"))

        rng = np.random.default_rng(0)
        ids = rng.choice(2 ** 32, 2048, replace=False).astype(np.uint64)
        B = 4096 * shards
        sk = pack_keys(rng.choice(ids, B), 32)
        dk = pack_keys(rng.choice(ids, B), 32)
        w = jnp.asarray(rng.uniform(0.5, 2, B).astype(np.float32))
        mask = jnp.ones(B, bool)
        qk = pack_keys(ids[:1024], 32)

        def mixed(state):
            state, _ = apply_fn(state, sk, dk, w, mask)
            cnt = khop(state, qk)
            return state, cnt

        t, (state, _) = timeit(mixed, state, iters=3)
        rows.append(("fig11", shards, round(B / t / 1e6, 3),
                     round(1024 / t / 1e6, 3)))
    return emit(rows)


if __name__ == "__main__":
    run()
