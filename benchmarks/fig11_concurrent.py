"""Paper Fig. 11: concurrent reads & writes — through the graph query service.

Thread-scaling becomes shard-scaling on the SPMD substrate, and the mixed
workload runs end-to-end through ``repro.api``: a ``ShardedStore`` feeds
``serve.GraphQueryService`` (writer ingests micro-batches, owner-routed
degree reads answer against sealed epochs, 1:1 interleave — the paper's
concurrent workload). After the stream drains, distributed BFS/PageRank
answers from the service are validated against a ``LocalStore`` running
the SAME AnalyticsOps — one API, two backends, dict-equal results (a
mismatch raises).

In-process runs measure the 1-shard configuration; multi-shard points run in
a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count``:

  PYTHONPATH=src python -m benchmarks.fig11_concurrent            # 1 + 4 shards
  PYTHONPATH=src python -m benchmarks.fig11_concurrent --shards 2 # one config
"""
from __future__ import annotations

import argparse
import os
import pathlib
import subprocess
import sys

import numpy as np

from .common import edge_stream, emit

HEADER = ("fig11", "shards", "write_Mops", "read_Mqps", "bfs_ok", "pr_err")
REPO = pathlib.Path(__file__).resolve().parents[1]


def run_one(shards: int, scale: float = 1.0, validate: bool = True):
    from repro.api import AnalyticsOp, OpBatch, make_store
    from repro.serve.graph_service import (GraphQueryService,
                                           drive_mixed_workload)

    n_v = max(256, int(1024 * scale))
    n_e = max(2048, int(16384 * scale))
    rng = np.random.default_rng(0)
    src, dst, ids = edge_stream(n_v, n_e, "powerlaw", seed=0)
    w = rng.uniform(0.5, 2, n_e).astype(np.float32)

    store = make_store("sharded", n_shards=shards, n_per_shard=8192,
                       expected_n=4096, pool_blocks=16384, block_size=16,
                       dmax=2048, k_max=128, batch=1024 * shards,
                       query_batch=256 * shards)
    svc = GraphQueryService(store, bfs_iters=32, pr_iters=20)

    qids = ids[:min(256 * shards, n_v)]
    dt, reads = drive_mixed_workload(svc, src, dst, w, qids)
    assert svc.stats["ops_dropped"] == 0

    tb = svc.submit_query("bfs", source=int(src[0]))
    tp = svc.submit_query("pagerank")
    svc.run()
    res = {tb: svc.claim(tb), tp: svc.claim(tp)}

    bfs_ok, pr_err = True, 0.0
    if validate:
        ref = make_store("local", n_max=4 * n_v, key_bits=32,
                         expected_n=n_v, batch=1024, pool_blocks=32768,
                         block_size=16, dmax=2048, k_max=128)
        ref.apply(OpBatch.edges(src, dst, w))
        ref_d = ref.analytics(AnalyticsOp("bfs", {"source": int(src[0]),
                                                  "max_iters": 32}))
        ref_pr = ref.analytics(AnalyticsOp("pagerank", {"iters": 20}))
        bfs_ok = res[tb] == ref_d       # same live-vertex keys, same depths
        assert set(res[tp]) == set(ref_pr)
        pr_err = max(abs(res[tp][v] - ref_pr[v]) for v in ref_pr) \
            if ref_pr else 0.0
        assert bfs_ok, "sharded BFS diverged from single-shard reference"
        assert pr_err < 1e-4, \
            f"sharded PageRank diverged from reference (max err {pr_err})"

    return [("fig11", shards, round(n_e / dt / 1e6, 5),
             round(reads / dt / 1e6, 5), bfs_ok, f"{pr_err:.2e}")]


def _subprocess_rows(shards: int, scale: float):
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={shards}",
           "PYTHONPATH": "src"}
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig11_concurrent",
         "--shards", str(shards), "--scale", str(scale)],
        capture_output=True, text=True, env=env, cwd=str(REPO), timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"fig11 {shards}-shard subprocess failed:\n"
                           + out.stderr[-2000:])
    return [tuple(ln.split(",")) for ln in out.stdout.splitlines()
            if ln.startswith("fig11,")]


def run(scale: float = 1.0):
    rows = [HEADER]
    rows += run_one(1, scale)
    rows += _subprocess_rows(4, scale)
    return emit(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=None,
                    help="run ONE config in-process (the parent sets "
                         "placeholder devices via XLA_FLAGS)")
    ap.add_argument("--scale", type=float, default=1.0)
    args = ap.parse_args(argv)
    if args.shards is None:
        run(args.scale)
    else:
        emit(run_one(args.shards, args.scale))


if __name__ == "__main__":
    main()
