"""Ingest fast-path throughput harness (updates/sec, fig8-style streams).

Measures steady-state edge-update throughput for batched powerlaw streams on

* the 1-shard ``RadixGraph`` host API (jitted padded batches), and
* the 4-shard distributed engine (subprocess with placeholder devices:
  route -> all_to_all -> apply, one fused SPMD program per batch),

at a small and a large batch size, and records the numbers in
``BENCH_ingest.json`` at the repo root.  The file keeps a ``before`` and an
``after`` section so every PR that touches the write path has a recorded
trajectory to beat:

    PYTHONPATH=src python -m benchmarks.bench_ingest --record after
    PYTHONPATH=src python -m benchmarks.bench_ingest --smoke   # CI artifact

``--record before`` is only used once per optimization PR, on the pre-change
tree; ``--record after`` (the default) refreshes the after section in place.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_ingest.json"

# one jit cache across batch configs would need one batch size; each config
# builds its own graph, so keep the stream modest and let compile warm out.
FULL = dict(n_vertices=8192, n_ops=65536)
SMOKE = dict(n_vertices=512, n_ops=4096)


def _throughput(n_ops: int, dt: float) -> float:
    return round(n_ops / dt, 1)


def bench_single(n_vertices: int, n_ops: int, batch: int, seed: int = 0):
    """1-shard ingest: batched powerlaw stream through the host API."""
    from benchmarks.common import GRAPH_CAPS, edge_stream
    from repro.core.radixgraph import RadixGraph

    src, dst, _ = edge_stream(n_vertices, n_ops + batch, "powerlaw", seed)
    kw = dict(GRAPH_CAPS)
    kw["batch"] = batch
    g = RadixGraph(key_bits=32, expected_n=n_vertices, undirected=False, **kw)
    g.add_edges(src[:batch], dst[:batch])            # compile + warm
    t0 = time.perf_counter()
    g.add_edges(src[batch:], dst[batch:])
    dt = time.perf_counter() - t0
    assert g.dropped_ops == 0 and not g.overflowed
    return {"batch": batch, "ops": n_ops, "seconds": round(dt, 3),
            "updates_per_s": _throughput(n_ops, dt),
            "live_edges": int(g.num_edges)}


def _shard_worker(n_vertices: int, n_ops: int, batch: int, n_shards: int,
                  seed: int = 0):
    """Runs inside the subprocess (placeholder devices already forced)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import AxisType

    from benchmarks.common import edge_stream
    from repro.core import edgepool as ep
    from repro.core.keys import pack_keys
    from repro.core.sort import SortSpec
    from repro.core.sort_optimizer import optimize_sort
    from repro.dist.graph_engine import make_apply_edges, make_sharded_state

    mesh = jax.make_mesh((n_shards,), ("data",),
                         devices=jax.devices()[:n_shards],
                         axis_types=(AxisType.Auto,))
    cfg = optimize_sort(max(256, n_vertices), 32, 5)
    sspec = SortSpec.from_config(cfg, 4 * max(1024, n_vertices))
    pspec = ep.PoolSpec(n_blocks=max(4096, 16 * n_vertices), block_size=16,
                        k_max=256, dmax=4096)
    state = make_sharded_state(sspec, pspec, n_shards,
                               4 * max(1024, n_vertices))
    apply_fn = jax.jit(make_apply_edges(sspec, pspec, mesh, "data"))

    src, dst, _ = edge_stream(n_vertices, n_ops + batch, "powerlaw", seed)
    sk = np.asarray(pack_keys(src, 32))
    dk = np.asarray(pack_keys(dst, 32))
    w = np.ones((batch,), np.float32)
    mask = np.ones((batch,), bool)

    def step(state, lo):
        return apply_fn(state, jnp.asarray(sk[lo:lo + batch]),
                        jnp.asarray(dk[lo:lo + batch]), jnp.asarray(w),
                        jnp.asarray(mask))

    state, dropped = step(state, 0)                  # compile + warm
    jax.block_until_ready(state)
    total_drop = 0
    t0 = time.perf_counter()
    for lo in range(batch, n_ops + batch, batch):
        state, dropped = step(state, lo)
        total_drop += int(np.asarray(dropped).sum())
    jax.block_until_ready(state)
    dt = time.perf_counter() - t0
    assert total_drop == 0, total_drop
    return {"batch": batch, "ops": n_ops, "seconds": round(dt, 3),
            "updates_per_s": _throughput(n_ops, dt), "shards": n_shards}


def bench_sharded(n_vertices: int, n_ops: int, batch: int, n_shards: int = 4):
    """Spawn the worker under ``--xla_force_host_platform_device_count``."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_shards}")
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{env.get('PYTHONPATH', '')}"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_ingest", "--_worker",
         json.dumps(dict(n_vertices=n_vertices, n_ops=n_ops, batch=batch,
                         n_shards=n_shards))],
        capture_output=True, text=True, env=env, cwd=str(ROOT), timeout=1800)
    for line in out.stdout.splitlines():
        if line.startswith("WORKER-RESULT "):
            return json.loads(line[len("WORKER-RESULT "):])
    raise RuntimeError(f"shard worker failed:\n{out.stderr[-2000:]}")


def run(smoke: bool = False, record: str = "after"):
    scale = SMOKE if smoke else FULL
    batches = (1024, 4096)
    results = {"one_shard": {}, "four_shard": {}}
    for b in batches:
        r = bench_single(scale["n_vertices"], scale["n_ops"], b)
        results["one_shard"][f"B{b}"] = r
        print(f"1-shard  B={b}: {r['updates_per_s']:.0f} updates/s "
              f"({r['ops']} ops in {r['seconds']}s)")
    for b in batches:
        r = bench_sharded(scale["n_vertices"], scale["n_ops"], b)
        results["four_shard"][f"B{b}"] = r
        print(f"4-shard  B={b}: {r['updates_per_s']:.0f} updates/s "
              f"({r['ops']} ops in {r['seconds']}s)")

    doc = {}
    if OUT.exists():
        doc = json.loads(OUT.read_text())
    doc.setdefault("bench", "ingest")
    if smoke:
        # CI sanity record: never clobbers the committed full-scale
        # before/after trajectory
        doc["smoke"] = dict(stream=dict(scale, dist="powerlaw",
                                        kind="insert"), **results)
    else:
        doc["scale"] = "full"
        doc["stream"] = dict(scale, dist="powerlaw", kind="insert")
        doc[record] = results
    OUT.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"[OK] wrote {OUT} ({'smoke' if smoke else record})")
    return doc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--record", choices=("before", "after"), default="after")
    ap.add_argument("--_worker", help="internal: JSON kwargs for the "
                    "in-subprocess shard worker")
    args = ap.parse_args(argv)
    if args._worker:
        res = _shard_worker(**json.loads(args._worker))
        print("WORKER-RESULT " + json.dumps(res))
        return res
    return run(smoke=args.smoke, record=args.record)


if __name__ == "__main__":
    main()
