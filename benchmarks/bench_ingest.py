"""Ingest fast-path throughput harness (updates/sec, fig8-style streams).

Measures steady-state edge-update throughput through the unified
``repro.api.GraphStore`` front door for batched streams on

* the 1-shard ``LocalStore`` (jitted padded batches), and
* the 4-shard ``ShardedStore`` (subprocess with placeholder devices:
  route -> all_to_all -> apply, one fused SPMD program per batch),

at a small and a large batch size, and records the numbers in
``BENCH_ingest.json`` at the repo root.  Three stream shapes:

* ``insert``  — plain powerlaw inserts (the historical before/after
  trajectory every write-path PR has to beat);
* ``mixed``   — fig9-style insert/update/delete stream (25% tombstones,
  powerlaw endpoints repeat, so updates occur naturally) exercising the
  probe's delete accounting under load;
* ``hub``     — hub-heavy stream where every batch overflows MANY
  over-window (tier-L) vertices: with more than ``k_big`` of them the
  fast path falls back to a global defrag (amortized-correct, recorded
  via the pool's ``defrags`` counter), while a raised ``k_big`` keeps the
  stream on the fast path — the knob trade the ROADMAP asks to record.

    PYTHONPATH=src python -m benchmarks.bench_ingest --record after
    PYTHONPATH=src python -m benchmarks.bench_ingest --smoke   # CI artifact

``--record before`` is only used once per optimization PR, on the pre-change
tree; ``--record after`` (the default) refreshes the after section in place.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_ingest.json"

# one jit cache across batch configs would need one batch size; each config
# builds its own graph, so keep the stream modest and let compile warm out.
# ``hub_ops`` gives the hub stream enough batches that every k_big budget
# below the hub count pays at least one overflow defrag (the smoke job
# asserts it — the spike path must actually run in CI).
FULL = dict(n_vertices=8192, n_ops=65536, hub_ops=65536, hub_n_hubs=48,
            hub_k_big=(16, 64))
SMOKE = dict(n_vertices=512, n_ops=4096, hub_ops=12288, hub_n_hubs=24,
             hub_k_big=(16, 64))


def _throughput(n_ops: int, dt: float) -> float:
    return round(n_ops / dt, 1)


def _latency_stats(lat: np.ndarray) -> dict:
    """Per-batch wall-time percentiles (ms) — the spike metric: a
    triggered defrag shows up as the gap between p50 and p99/max."""
    ms = np.asarray(lat) * 1000.0
    return {"p50_ms": round(float(np.percentile(ms, 50)), 2),
            "p99_ms": round(float(np.percentile(ms, 99)), 2),
            "max_ms": round(float(ms.max()), 2)}


def _batched_apply(store, src, dst, w, batch):
    """Apply the stream one device batch per call, timing each batch."""
    from repro.api import OpBatch
    lat = []
    for lo in range(0, len(src), batch):
        t0 = time.perf_counter()
        res = store.apply(OpBatch.edges(
            src[lo:lo + batch], dst[lo:lo + batch],
            None if w is None else w[lo:lo + batch]))
        lat.append(time.perf_counter() - t0)
        assert res.dropped == 0
    return np.asarray(lat)


def _mixed_weights(n: int, seed: int = 1) -> np.ndarray:
    """fig9-style op mix: uniform weights, 25% NULL tombstones (deletes);
    powerlaw endpoint reuse supplies the updates."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 2.0, n).astype(np.float32)
    w[rng.random(n) < 0.25] = 0.0
    return w


def _hub_stream(n_vertices: int, n_ops: int, n_hubs: int, seed: int = 0):
    """Every op's source is one of ``n_hubs`` hubs (round-robin, so each
    batch touches every hub): hub edge arrays quickly outgrow the probe
    window and overflow per batch — the tier-L (k_big) stress shape."""
    rng = np.random.default_rng(seed)
    ids = rng.choice(2 ** 32, n_vertices, replace=False).astype(np.uint64)
    hubs = ids[:n_hubs]
    src = hubs[np.arange(n_ops) % n_hubs]
    dst = ids[rng.integers(0, n_vertices, n_ops)]
    return src, dst, ids


def _local_store(n_vertices: int, batch: int, **over):
    from benchmarks.common import GRAPH_CAPS
    from repro.api import make_store
    kw = dict(GRAPH_CAPS)
    kw["batch"] = batch
    kw.update(over)
    return make_store("local", key_bits=32, expected_n=n_vertices,
                      undirected=False, **kw)


def bench_single(n_vertices: int, n_ops: int, batch: int, seed: int = 0,
                 weights=None, **store_over):
    """1-shard ingest: a batched powerlaw stream through ``LocalStore``,
    timed per device batch so the latency PERCENTILES (not just the mean
    throughput) are a recorded artifact."""
    from benchmarks.common import edge_stream
    from repro.api import OpBatch, ReadOp

    warm = 2 * batch   # batch 1 compiles the non-donating program (fresh
    #                    states are donation-pinned), batch 2 the donated
    #                    steady-state executable — both stay out of timing
    src, dst, _ = edge_stream(n_vertices, n_ops + warm, "powerlaw", seed)
    w = weights(n_ops + warm) if weights is not None else None
    store = _local_store(n_vertices, batch, **store_over)
    for lo in (0, batch):
        store.apply(OpBatch.edges(src[lo:lo + batch], dst[lo:lo + batch],
                                  None if w is None else w[lo:lo + batch]))
    lat = _batched_apply(store, src[warm:], dst[warm:],
                         None if w is None else w[warm:], batch)
    dt = float(lat.sum())
    assert not store.graph.overflowed
    return {"batch": batch, "ops": n_ops, "seconds": round(dt, 3),
            "updates_per_s": _throughput(n_ops, dt),
            **_latency_stats(lat),
            "tiles_scanned": store.stats["tiles_scanned"],
            "live_edges": store.read(ReadOp("num_edges"))}


def bench_hub(n_vertices: int, n_ops: int, batch: int, n_hubs: int,
              k_big: int, seed: int = 0, defrag_impl: str = "auto"):
    """Hub-heavy tier-L stress: same stream at two ``k_big`` budgets —
    the small one records overflow-defrag fallbacks (and their wall-time
    spike via ``defrag_ms`` / the p99-over-p50 gap), the raised one stays
    on the fast path (each unit of k_big costs one dmax-width compaction
    row per batch)."""
    from repro.api import OpBatch, ReadOp

    warm = 2 * batch   # both program variants compile out of the timing
    src, dst, _ = _hub_stream(n_vertices, n_ops + warm, n_hubs, seed)
    store = _local_store(n_vertices, batch, k_big=k_big,
                         defrag_impl=defrag_impl)
    for lo in (0, batch):
        store.apply(OpBatch.edges(src[lo:lo + batch], dst[lo:lo + batch]))
    d0 = store.graph.num_defrags
    lat = _batched_apply(store, src[warm:], dst[warm:], None, batch)
    dt = float(lat.sum())
    assert not store.graph.overflowed
    return {"batch": batch, "ops": n_ops, "n_hubs": n_hubs,
            "k_big": k_big, "seconds": round(dt, 3),
            "updates_per_s": _throughput(n_ops, dt),
            **_latency_stats(lat),
            "overflow_defrags": store.graph.num_defrags - d0,
            "defrag_ms": round(store.graph.defrag_ms, 1),
            # the spike decomposed: host staging (python + dispatch) vs
            # the blocked-on-device sync at the rebuild boundary
            "defrag_host_ms": round(store.graph.defrag_host_ms, 1),
            "defrag_sync_ms": round(store.graph.defrag_sync_ms, 1),
            "tiles_scanned": store.stats["tiles_scanned"],
            "live_edges": store.read(ReadOp("num_edges"))}


def bench_defrag(n_vertices: int, n_ops: int, batch: int, n_hubs: int,
                 seed: int = 0, iters: int = 3):
    """Explicit-rebuild microbench: the SAME hub-loaded state rebuilt by
    the dense entry-scatter reference and by the streaming block-row
    path — the before/after of the defrag spike, isolated from the
    ingest around it (``k_big`` is raised so loading never rebuilds)."""
    import jax

    from repro.api import OpBatch
    from repro.core import radixgraph as rg

    out = {}
    for impl in ("dense", "stream"):
        store = _local_store(n_vertices, batch, k_big=64, defrag_impl=impl)
        src, dst, _ = _hub_stream(n_vertices, n_ops, n_hubs, seed)
        _batched_apply(store, src, dst, None, batch)
        g = store.graph
        st = g.state
        r = rg._defrag(g.sort_spec, g.pool_spec, st)   # compile + warm
        jax.block_until_ready(r)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            r = rg._defrag(g.sort_spec, g.pool_spec, st)
            jax.block_until_ready(r)
            ts.append(time.perf_counter() - t0)
        out[impl] = {"seconds": round(float(np.median(ts)), 3),
                     "defrags_during_load": g.num_defrags}
    out["speedup"] = round(out["dense"]["seconds"] /
                           max(out["stream"]["seconds"], 1e-9), 1)
    return out


def _shard_worker(n_vertices: int, n_ops: int, batch: int, n_shards: int,
                  seed: int = 0, mixed: bool = False, pipeline: int = 8):
    """Runs inside the subprocess (placeholder devices already forced).

    ``pipeline`` is the flush depth: each ``store.apply`` stages
    ``pipeline`` device batches and dispatches them back-to-back (donated
    steady-state buffers, a single host sync per flush). It is capped at
    the stream's batch count so short (smoke) streams never retrace a
    ragged depth inside the timed region."""
    import jax

    from benchmarks.common import edge_stream
    from repro.api import OpBatch, make_store

    pipeline = max(1, min(pipeline, n_ops // batch))
    store = make_store(
        "sharded", n_shards=n_shards,
        n_per_shard=4 * max(1024, n_vertices),
        expected_n=max(256, n_vertices),
        pool_blocks=max(4096, 16 * n_vertices), block_size=16,
        k_max=256, dmax=4096, batch=batch, pipeline_depth=pipeline,
        sync_incremental=False)     # measure the raw routed-apply path

    chunk = pipeline * batch        # ops per flush (one apply call)
    warm = 2 * chunk                # see below
    src, dst, _ = edge_stream(n_vertices, n_ops + warm, "powerlaw", seed)
    w = _mixed_weights(n_ops + warm) if mixed else \
        np.ones(n_ops + warm, np.float32)

    # warm BOTH program variants before timing: the first dispatch runs the
    # non-donating program (fresh states are donation-pinned), every later
    # one the donated executable — a separate compile that must not land in
    # the timed region (it did once: ~12s mistaken for steady-state cost)
    for lo in range(0, warm, chunk):
        store.apply(OpBatch.edges(src[lo:lo + chunk], dst[lo:lo + chunk],
                                  w[lo:lo + chunk]))
    jax.block_until_ready(store.state)
    for k in ("flushes", "super_batches", "host_stage_ms", "device_sync_ms"):
        store.stats[k] = 0          # report the timed region only
    t0 = time.perf_counter()
    for lo in range(warm, n_ops + warm, chunk):
        store.apply(OpBatch.edges(src[lo:lo + chunk], dst[lo:lo + chunk],
                                  w[lo:lo + chunk]))
    jax.block_until_ready(store.state)
    dt = time.perf_counter() - t0
    assert store.stats["ops_dropped"] == 0, store.stats
    sb = max(1, store.stats["super_batches"])
    return {"batch": batch, "ops": n_ops, "seconds": round(dt, 3),
            "updates_per_s": _throughput(n_ops, dt), "shards": n_shards,
            "tiles_scanned": store.stats["tiles_scanned"],
            "defrags": store.stats["defrags"],
            "defrag_host_ms": store.stats["defrag_host_ms"],
            "defrag_sync_ms": store.stats["defrag_sync_ms"],
            "pipeline_depth": pipeline,
            "flushes": store.stats["flushes"],
            "super_batches": store.stats["super_batches"],
            # per-super-batch host-overhead vs device-time breakdown: the
            # stage side is python staging + async dispatch, the sync side
            # is the once-per-flush blocked-on-device fetch
            "host_ms_per_super_batch": round(
                store.stats["host_stage_ms"] / sb, 2),
            "device_ms_per_super_batch": round(
                (dt * 1000.0 - store.stats["host_stage_ms"]) / sb, 2),
            "kind": "mixed" if mixed else "insert"}


def bench_sharded(n_vertices: int, n_ops: int, batch: int, n_shards: int = 4,
                  mixed: bool = False, pipeline: int = 8):
    """Spawn the worker under ``--xla_force_host_platform_device_count``."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_shards}")
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{env.get('PYTHONPATH', '')}"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_ingest", "--_worker",
         json.dumps(dict(n_vertices=n_vertices, n_ops=n_ops, batch=batch,
                         n_shards=n_shards, mixed=mixed, pipeline=pipeline))],
        capture_output=True, text=True, env=env, cwd=str(ROOT), timeout=1800)
    for line in out.stdout.splitlines():
        if line.startswith("WORKER-RESULT "):
            return json.loads(line[len("WORKER-RESULT "):])
    raise RuntimeError(f"shard worker failed:\n{out.stderr[-2000:]}")


def run(smoke: bool = False, record: str = "after"):
    scale = SMOKE if smoke else FULL
    nv, no = scale["n_vertices"], scale["n_ops"]
    batches = (1024, 4096)
    results = {"one_shard": {}, "four_shard": {}, "mixed": {}, "hub": {},
               "pipeline": {}}
    for b in batches:
        r = bench_single(nv, no, b)
        results["one_shard"][f"B{b}"] = r
        print(f"1-shard  B={b}: {r['updates_per_s']:.0f} updates/s "
              f"({r['ops']} ops in {r['seconds']}s)")
    for b in batches:
        r = bench_sharded(nv, no, b)
        results["four_shard"][f"B{b}"] = r
        print(f"4-shard  B={b}: {r['updates_per_s']:.0f} updates/s "
              f"({r['ops']} ops in {r['seconds']}s)")
    # fig9-style mixed insert/update/delete trajectory (1- and 4-shard)
    r = bench_single(nv, no, 4096, weights=_mixed_weights)
    results["mixed"]["one_shard_B4096"] = r
    print(f"mixed 1-shard  B=4096: {r['updates_per_s']:.0f} updates/s "
          f"({r['live_edges']} live edges)")
    r = bench_sharded(nv, no, 4096, mixed=True)
    results["mixed"]["four_shard_B4096"] = r
    print(f"mixed 4-shard  B=4096: {r['updates_per_s']:.0f} updates/s")
    # the pipelined-path depth sweep: the SAME 4-shard stream at K=1 (one
    # host sync per batch — the PR-5 shape) vs K=8 (8 donated dispatches
    # per flush sync), with the per-super-batch host/device breakdown
    pb = 512 if smoke else 4096
    for K in (1, 8):
        r = bench_sharded(nv, no, pb, pipeline=K)
        results["pipeline"][f"K{K}"] = r
        print(f"pipeline K={K} B={pb}: {r['updates_per_s']:.0f} updates/s "
              f"({r['super_batches']} super-batches, host "
              f"{r['host_ms_per_super_batch']} ms / device "
              f"{r['device_ms_per_super_batch']} ms per super-batch)")
    k1 = results["pipeline"]["K1"]["updates_per_s"]
    k8 = results["pipeline"]["K8"]["updates_per_s"]
    results["pipeline"]["speedup_K8_over_K1"] = round(k8 / k1, 2)
    if smoke:
        # CI gate: the deep pipeline must not be slower than per-batch
        # flushing (5% floor absorbs single-core scheduling noise)
        assert k8 >= 0.95 * k1, results["pipeline"]
    # hub-heavy tier-L budget: small k_big falls back to defrag, raised
    # k_big rides the fast path — record both sides of the knob, plus the
    # per-batch latency spike the triggered rebuilds cost
    for kb in scale["hub_k_big"]:
        r = bench_hub(nv, scale["hub_ops"], 4096, scale["hub_n_hubs"], kb)
        results["hub"][f"k_big{kb}"] = r
        print(f"hub({scale['hub_n_hubs']} hubs) k_big={kb}: "
              f"{r['updates_per_s']:.0f} updates/s, "
              f"{r['overflow_defrags']} overflow defrags "
              f"({r['defrag_ms']} ms), p50 {r['p50_ms']} / "
              f"p99 {r['p99_ms']} ms")
        if smoke and kb < scale["hub_n_hubs"]:
            # the CI smoke must actually exercise the overflow-defrag
            # path — a budget below the hub count has to rebuild
            assert r["overflow_defrags"] >= 1, r
    # the defrag spike itself, dense reference vs streaming rebuild
    r = bench_defrag(nv, scale["hub_ops"], 4096, scale["hub_n_hubs"])
    results["defrag"] = r
    print(f"defrag: dense {r['dense']['seconds']}s vs stream "
          f"{r['stream']['seconds']}s ({r['speedup']}x)")

    doc = {}
    if OUT.exists():
        doc = json.loads(OUT.read_text())
    doc.setdefault("bench", "ingest")
    if smoke:
        # CI sanity record: never clobbers the committed full-scale
        # before/after trajectory
        doc["smoke"] = dict(stream=dict(scale, dist="powerlaw"), **results)
    else:
        doc["scale"] = "full"
        doc["stream"] = dict(scale, dist="powerlaw")
        doc[record] = results
    OUT.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"[OK] wrote {OUT} ({'smoke' if smoke else record})")
    return doc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--record", choices=("before", "after"), default="after")
    ap.add_argument("--_worker", help="internal: JSON kwargs for the "
                    "in-subprocess shard worker")
    args = ap.parse_args(argv)
    if args._worker:
        res = _shard_worker(**json.loads(args._worker))
        print("WORKER-RESULT " + json.dumps(res))
        return res
    return run(smoke=args.smoke, record=args.record)


if __name__ == "__main__":
    main()
