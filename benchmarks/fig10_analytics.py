"""Paper Fig. 10: k-hop neighbor query throughput + GAPBS analytics latency
(BFS, SSSP, PR, WCC, TC, BC) on the RadixGraph snapshot."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro import analytics as A
from repro.core.radixgraph import RadixGraph

from .common import dataset, emit, timeit


def run(scale: float = 1.0, datasets=("lj", "dota", "u24")):
    rows = [("fig10", "dataset", "task", "latency_ms", "throughput_qps")]
    for ds in datasets:
        src, dst, ids = dataset(ds, scale)
        n = len(ids)
        from .common import make_graph
        g = make_graph("snaplog")
        g.add_edges(src, dst)
        # tight CSR pad: analytics cost scales with m_cap, not live edges
        m_cap = 1 << (2 * len(src) * 2 + 1024).bit_length()
        t_snap, snap = timeit(g.snapshot, m_cap=m_cap, iters=2)
        rows.append(("fig10", ds, "snapshot_build", round(t_snap * 1e3, 2), ""))
        off = g.lookup(ids)
        Q = min(512, n)
        qoff = jnp.asarray(off[:Q], jnp.int32)
        for k in (1, 2):
            t, _ = timeit(A.khop, snap, qoff, k=k, iters=2)
            rows.append(("fig10", ds, f"{k}-hop", round(t * 1e3, 2),
                         round(Q / t, 1)))
        s0 = jnp.int32(int(off[0]))
        for name, fn in (
            ("BFS", lambda: A.bfs(snap, s0)),
            ("SSSP", lambda: A.sssp(snap, s0)),
            ("PR", lambda: A.pagerank(snap, iters=20)),
            ("WCC", lambda: A.wcc(snap)),
            ("TC", lambda: A.triangle_count(snap)),
            ("BC", lambda: A.bc(snap, qoff[:16])),
        ):
            t, _ = timeit(fn, iters=2)
            rows.append(("fig10", ds, name, round(t * 1e3, 2), ""))
    return emit(rows)


if __name__ == "__main__":
    run()
