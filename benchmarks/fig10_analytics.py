"""Paper Fig. 10: k-hop neighbor query throughput + GAPBS analytics latency
(BFS, SSSP, PR, WCC, TC, BC) — driven through ``repro.api.GraphStore``:
every task is one ``AnalyticsOp``/``ReadOp`` against a ``LocalStore``, the
same ops the sharded backend answers (swap ``make_store('sharded', ...)``
to scale the identical workload out).

Rows measure API-level latency: the jitted kernel PLUS the store's ID
resolution and ``{vertex_id: value}`` normalization — what a caller of the
front door actually observes (slightly above the raw-kernel rows recorded
before the GraphStore migration)."""
from __future__ import annotations

import numpy as np

from repro.api import AnalyticsOp, OpBatch, ReadOp, make_store

from .common import GRAPH_CAPS, dataset, emit, timeit


def run(scale: float = 1.0, datasets=("lj", "dota", "u24")):
    rows = [("fig10", "dataset", "task", "latency_ms", "throughput_qps")]
    for ds in datasets:
        src, dst, ids = dataset(ds, scale)
        n = len(ids)
        # tight CSR pad: analytics cost scales with m_cap, not live edges
        m_cap = 1 << (2 * len(src) * 2 + 1024).bit_length()
        store = make_store("local", key_bits=32, expected_n=8192,
                           undirected=True, m_cap=m_cap, **GRAPH_CAPS)
        store.apply(OpBatch.edges(src, dst))
        t_snap, _ = timeit(store.read, ReadOp("snapshot"), iters=2)
        rows.append(("fig10", ds, "snapshot_build", round(t_snap * 1e3, 2),
                     ""))
        Q = min(512, n)
        qids = ids[:Q]
        for k in (1, 2):
            t, _ = timeit(store.analytics,
                          AnalyticsOp("khop", {"sources": qids, "k": k}),
                          iters=2)
            rows.append(("fig10", ds, f"{k}-hop", round(t * 1e3, 2),
                         round(Q / t, 1)))
        s0 = int(src[0])
        for name, op in (
            ("BFS", AnalyticsOp("bfs", {"source": s0, "max_iters": 64})),
            ("SSSP", AnalyticsOp("sssp", {"source": s0})),
            ("PR", AnalyticsOp("pagerank", {"iters": 20})),
            ("WCC", AnalyticsOp("wcc")),
            ("TC", AnalyticsOp("triangle_count")),
            ("BC", AnalyticsOp("bc", {"sources": qids[:16]})),
        ):
            t, _ = timeit(store.analytics, op, iters=2)
            rows.append(("fig10", ds, name, round(t * 1e3, 2), ""))
    return emit(rows)


if __name__ == "__main__":
    run()
