"""Paper Fig. 12: SORT case study — optimal fanouts vs n, updated-vs-trailing
config memory, linear space growth, and transformation (rebuild) cost."""
from __future__ import annotations

import numpy as np

from repro.core import sort as sort_mod
from repro.core.keys import pack_keys
from repro.core.sort import SortSpec
from repro.core.sort_optimizer import expected_space, optimize_sort

from .common import emit, timeit

import jax.numpy as jnp


def _insert(spec, ids):
    st = sort_mod.make_sort(spec)
    return sort_mod.insert_mappings(
        spec, st, pack_keys(ids, 32),
        jnp.arange(len(ids), dtype=jnp.int32), jnp.ones(len(ids), bool))


def run(scale: float = 1.0):
    rows = [("fig12a", "n", "optimal_fanouts", "expected_slots")]
    ns = [10_000, 50_000, 100_000, 300_000, 1_000_000, 3_000_000, 10_000_000]
    configs = {}
    for n in ns:
        c = optimize_sort(n, 32, 5)
        configs[n] = c
        rows.append(("fig12a", n, "|".join(map(str, c.fanout_bits)),
                     int(c.expected_space)))
    # (b) updated vs trailing config memory (objective value comparison)
    for i in range(1, len(ns)):
        n = ns[i]
        upd = configs[n]
        trail = configs[ns[i - 1]]
        rows.append(("fig12b", n,
                     f"updated={int(upd.expected_space)}",
                     f"trailing={int(expected_space(trail.fanout_bits, 32, n))}"))
    # (c) measured materialized slots ~ linear in n; (d) transformation cost
    rng = np.random.default_rng(0)
    for n in (int(20_000 * scale), int(60_000 * scale), int(120_000 * scale)):
        ids = rng.choice(2 ** 32, n, replace=False).astype(np.uint64)
        cfg = optimize_sort(n, 32, 5)
        spec = SortSpec.from_config(cfg, n + 8)
        t_build, st = timeit(_insert, spec, ids, iters=1, warmup=0)
        slots = int(sort_mod.materialized_slots(spec, st))
        rows.append(("fig12c", n, slots, round(slots / n, 2)))
        # transformation = rebuild under the next config (lazy adaptation
        # upper bound: full reinsert)
        cfg2 = optimize_sort(2 * n, 32, 5)
        spec2 = SortSpec.from_config(cfg2, 2 * n + 8)
        t_tr, _ = timeit(_insert, spec2, ids, iters=1, warmup=0)
        rows.append(("fig12d", n, f"transform_ms={round(t_tr * 1e3, 1)}",
                     f"build_ms={round(t_build * 1e3, 1)}"))
    return emit(rows)


if __name__ == "__main__":
    run()
