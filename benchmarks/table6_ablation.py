"""Paper Table 6 ablations:
  (a) SORT vs ART as RadixGraph's vertex index — the ID-translation
      component is benchmarked head-to-head on the graph's real ID stream;
  (b) edge chain on/off — multi-hop analytics pay a per-hop ID->offset
      SORT round-trip when the chain is disabled (the prior-systems layout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import analytics as A
from repro.baselines import JaxART
from repro.core import sort as sort_mod
from repro.core.radixgraph import RadixGraph

from .common import dataset, emit, timeit


def _bfs_without_chain(g, snap, src_off, max_iters=32):
    """Level-synchronous BFS where every hop re-translates IDs through the
    vertex index (edge blocks store IDs, not offsets)."""
    ids = np.asarray(g.state.vt.ids)
    n = snap.indptr.shape[0] - 1
    depth = np.full(n, -1, np.int32)
    depth[src_off] = 0
    frontier = [src_off]
    indptr = np.asarray(snap.indptr)
    dst = np.asarray(snap.dst)
    it = 0
    while frontier and it < max_iters:
        it += 1
        nxt = set()
        offs = np.asarray(frontier)
        for o in offs:
            nbr_off = dst[indptr[o]:indptr[o + 1]]
            # chain OFF: pretend blocks held IDs -> translate via SORT
            hi = ids[nbr_off, 0].astype(np.uint64) << np.uint64(32)
            nbr_ids = hi | ids[nbr_off, 1].astype(np.uint64)
            back = g.lookup(nbr_ids)          # the extra per-hop lookups
            for b in back:
                if b >= 0 and depth[b] < 0:
                    depth[b] = it
                    nxt.add(int(b))
        frontier = list(nxt)
    return depth


def run(scale: float = 1.0, datasets=("lj", "dota")):
    rows = [("table6", "dataset", "ablation", "metric", "value")]
    for ds in datasets:
        src, dst, ids = dataset(ds, scale)
        n = len(ids)
        from .common import make_graph
        g = make_graph("snaplog")
        g.add_edges(src, dst)
        snap = g.snapshot(m_cap=1 << (2 * len(src) * 2 + 1024).bit_length())
        off = g.lookup(ids)

        # (a) vertex-index swap: translation throughput on the real stream
        stream = np.concatenate([src, dst])
        t_sort, _ = timeit(lambda: g.lookup(stream), iters=2)
        art = JaxART(n_max=8192)
        art.insert(ids, np.asarray(off, np.int32))
        t_art, _ = timeit(lambda: art.lookup(stream), iters=2)
        rows.append(("table6", ds, "ART-vs-SORT", "lookup_slowdown_x",
                     round(t_art / t_sort, 2)))

        # (b) edge chain ablation
        s0 = jnp.int32(int(off[0]))
        t_chain, _ = timeit(lambda: A.bfs(snap, s0), iters=2)
        t_nochain, _ = timeit(_bfs_without_chain, g, snap, int(off[0]),
                              iters=1, warmup=0)
        rows.append(("table6", ds, "edge-chain", "bfs_slowdown_wo_chain_x",
                     round(t_nochain / t_chain, 2)))
        Q = min(256, n)
        qoff = jnp.asarray(off[:Q], jnp.int32)
        t2, _ = timeit(A.khop, snap, qoff, k=2, iters=2)

        def two_hop_nochain():
            # hop 1 from snapshot, then translate + look up before hop 2
            one = A.khop(snap, qoff, k=1)
            ids_np = np.asarray(g.state.vt.ids)
            hi = ids_np[np.asarray(qoff), 0].astype(np.uint64) << np.uint64(32)
            back = g.lookup(hi | ids_np[np.asarray(qoff), 1].astype(np.uint64))
            return A.khop(snap, jnp.asarray(back, jnp.int32), k=2)

        t2n, _ = timeit(two_hop_nochain, iters=2)
        rows.append(("table6", ds, "edge-chain", "2hop_slowdown_wo_chain_x",
                     round(t2n / t2, 2)))
    return emit(rows)


if __name__ == "__main__":
    run()
