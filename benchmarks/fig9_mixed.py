"""Paper Fig. 9: mixed edge updates (insert/update/delete stream) time
footprint at 20%..100% checkpoints + memory during large-scale deletions."""
from __future__ import annotations

import time

import numpy as np

from repro.core.radixgraph import RadixGraph

from .common import dataset, emit


def run(scale: float = 1.0):
    rows = [("fig9", "dataset", "system", "pct", "elapsed_s", "memory_mb")]
    for ds in ("g24", "u24"):
        src, dst, ids = dataset(ds, scale)
        m = len(src)
        rng = np.random.default_rng(1)
        w = rng.uniform(0.5, 2.0, m).astype(np.float32)
        kind = rng.random(m)
        w[kind < 0.25] = 0.0                      # 25% deletions
        for policy in ("snaplog", "grow", "sorted"):
            from .common import make_graph
            g = make_graph(policy)
            name = {"snaplog": "RadixGraph", "grow": "log-store",
                    "sorted": "sorted+buffer"}[policy]
            t0 = time.perf_counter()
            for pct in (20, 40, 60, 80, 100):
                lo, hi = m * (pct - 20) // 100, m * pct // 100
                g.apply_ops(src[lo:hi], dst[lo:hi], w[lo:hi])
                rows.append(("fig9", ds, name, pct,
                             round(time.perf_counter() - t0, 3),
                             round(g.memory_bytes() / 2 ** 20, 2)))
        # deletion memory footprint (Fig. 9c/d): delete everything in waves
        from .common import make_graph
        g = make_graph("snaplog")
        g.add_edges(src, dst)
        for pct in (25, 50, 75, 100):
            lo, hi = m * (pct - 25) // 100, m * pct // 100
            g.delete_edges(src[lo:hi], dst[lo:hi])
            rows.append(("fig9-del", ds, "RadixGraph", pct, "",
                         round(g.memory_bytes() / 2 ** 20, 2)))
    return emit(rows)


if __name__ == "__main__":
    run()
