"""Durability overhead harness: WAL group-commit cost, checkpoint
full-vs-delta cost, and recovery time, recorded in ``BENCH_durability.json``.

Three questions, one artifact:

* **WAL tax** — the same powerlaw ingest stream through a bare
  ``LocalStore`` and through ``DurableStore`` at group-commit 1 / 8 / 32
  / 256 (1 = fsync every batch, the paranoid setting; 256 ≈ free). The
  ratio column is the headline: the default (32) must stay within 30% of
  the WAL-off throughput (CI gate in ``--smoke``).
* **checkpoint cost** — a full checkpoint of the loaded store vs an
  incremental one after a short additional stream: wall ms and on-disk
  bytes for each, plus the delta's touched-block count.
* **recovery** — wall time of ``recover()`` (checkpoint chain + WAL
  suffix replay) and a bit-exactness flag against the uninterrupted
  store's epoch snapshot.

    PYTHONPATH=src python -m benchmarks.bench_durability --record after
    PYTHONPATH=src python -m benchmarks.bench_durability --smoke   # CI
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import tempfile
import time

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_durability.json"

FULL = dict(n_vertices=8192, n_ops=65536, batch=4096, tail_ops=8192)
SMOKE = dict(n_vertices=512, n_ops=8192, batch=1024, tail_ops=2048)

GROUP_COMMITS = (1, 8, 32, 256)
DEFAULT_GC = 32


def _store(n_vertices: int, batch: int):
    from benchmarks.common import GRAPH_CAPS
    from repro.api import make_store
    kw = dict(GRAPH_CAPS)
    kw["batch"] = batch
    return make_store("local", key_bits=32, expected_n=n_vertices,
                      undirected=False, **kw)


def _ingest(store, src, dst, w, batch):
    from repro.api import OpBatch
    t0 = time.perf_counter()
    for lo in range(0, len(src), batch):
        store.apply(OpBatch.edges(src[lo:lo + batch], dst[lo:lo + batch],
                                  w[lo:lo + batch]))
    return time.perf_counter() - t0


def _stream(n_vertices: int, n_ops: int, seed: int = 0):
    from benchmarks.common import edge_stream
    src, dst, _ = edge_stream(n_vertices, n_ops, "powerlaw", seed)
    w = np.random.default_rng(seed + 1).uniform(
        0.5, 2.0, n_ops).astype(np.float32)
    return src, dst, w


def _snapshot_leaves(store):
    import jax
    from repro.api import ReadOp
    snap = store.read(ReadOp("snapshot"))
    return [np.asarray(x) for x in jax.tree.leaves(snap)]


def bench_wal(nv: int, n_ops: int, batch: int):
    """WAL-off vs WAL-on throughput at each group-commit setting (same
    stream, warm batches excluded so jit compilation stays out)."""
    from repro.api import OpBatch
    from repro.storage import DurableStore

    warm = 2 * batch
    src, dst, w = _stream(nv, n_ops + warm)
    out = {}

    base = _store(nv, batch)
    for lo in (0, batch):
        base.apply(OpBatch.edges(src[lo:lo + batch], dst[lo:lo + batch],
                                 w[lo:lo + batch]))
    dt = _ingest(base, src[warm:], dst[warm:], w[warm:], batch)
    out["wal_off"] = {"seconds": round(dt, 3),
                      "updates_per_s": round(n_ops / dt, 1)}
    print(f"WAL off          : {n_ops / dt:10.0f} updates/s")

    for gc in GROUP_COMMITS:
        d = tempfile.mkdtemp(prefix=f"bench_dur_gc{gc}_")
        store = DurableStore(_store(nv, batch), d, group_commit=gc)
        for lo in (0, batch):
            store.apply(OpBatch.edges(src[lo:lo + batch],
                                      dst[lo:lo + batch],
                                      w[lo:lo + batch]))
        dt = _ingest(store, src[warm:], dst[warm:], w[warm:], batch)
        store.sync()
        r = {"seconds": round(dt, 3),
             "updates_per_s": round(n_ops / dt, 1),
             "vs_wal_off": round(out["wal_off"]["seconds"] / dt, 3),
             "wal_bytes": store.stats["wal_bytes"],
             "wal_syncs": store.stats["wal_syncs"]}
        out[f"group_commit_{gc}"] = r
        print(f"WAL gc={gc:<4d}     : {n_ops / dt:10.0f} updates/s "
              f"({r['vs_wal_off']:.2f}x of WAL-off, {r['wal_syncs']} "
              f"fsyncs, {r['wal_bytes']} bytes)")
        store.close()
        shutil.rmtree(d, ignore_errors=True)
    return out


def bench_checkpoint_and_recovery(nv: int, n_ops: int, batch: int,
                                  tail_ops: int):
    """Checkpoint full vs delta cost on a loaded store, then recovery
    wall time + bit-exactness (checkpoint chain + WAL suffix replay)."""
    from repro.api import OpBatch, ReadOp, make_store  # noqa: F401
    from repro.storage import DurableStore, recover

    d = tempfile.mkdtemp(prefix="bench_dur_ckpt_")
    src, dst, w = _stream(nv, n_ops + 2 * tail_ops)
    store = DurableStore(_store(nv, batch), d, group_commit=DEFAULT_GC)
    _ingest(store, src[:n_ops], dst[:n_ops], w[:n_ops], batch)

    t0 = time.perf_counter()
    man_full = store.checkpoint()
    full_ms = (time.perf_counter() - t0) * 1000.0
    assert man_full["kind"] == "full"

    lo = n_ops
    _ingest(store, src[lo:lo + tail_ops], dst[lo:lo + tail_ops],
            w[lo:lo + tail_ops], batch)
    t0 = time.perf_counter()
    man_delta = store.checkpoint()
    delta_ms = (time.perf_counter() - t0) * 1000.0

    # WAL suffix beyond the last checkpoint, so recovery has replaying
    # to do on top of the chain
    lo = n_ops + tail_ops
    _ingest(store, src[lo:lo + tail_ops], dst[lo:lo + tail_ops],
            w[lo:lo + tail_ops], batch)
    store.sync()
    live_leaves = _snapshot_leaves(store)
    live_edges = store.read(ReadOp("num_edges"))
    store.close()

    t0 = time.perf_counter()
    rec, report = recover(d, lambda: _store(nv, batch))
    recover_s = time.perf_counter() - t0
    bit_exact = (rec.read(ReadOp("num_edges")) == live_edges and
                 all(np.array_equal(a, b) for a, b in
                     zip(live_leaves, _snapshot_leaves(rec))))
    rec.close()
    shutil.rmtree(d, ignore_errors=True)
    out = {
        "full": {"ms": round(full_ms, 1), "bytes": man_full["bytes"]},
        "delta": {"ms": round(delta_ms, 1), "bytes": man_delta["bytes"],
                  "kind": man_delta["kind"],
                  "touched_blocks": (man_delta.get("delta") or {}).get(
                      "n_blocks"),
                  "vs_full_bytes": round(
                      man_delta["bytes"] / man_full["bytes"], 3)},
        "recovery": {"seconds": round(recover_s, 3),
                     "replayed": report["replayed"],
                     "checkpoint_kind": report["checkpoint_kind"],
                     "bit_exact": bool(bit_exact)},
    }
    print(f"checkpoint full  : {full_ms:.0f} ms, {man_full['bytes']} B")
    print(f"checkpoint delta : {delta_ms:.0f} ms, {man_delta['bytes']} B "
          f"({out['delta']['vs_full_bytes']:.2f}x of full, "
          f"kind={man_delta['kind']})")
    print(f"recovery         : {recover_s:.3f} s "
          f"({report['checkpoint_kind']} ckpt + {report['replayed']} "
          f"records), bit_exact={bit_exact}")
    assert bit_exact, "recovered store diverged from the live one"
    return out


def run(smoke: bool = False, record: str = "after"):
    scale = SMOKE if smoke else FULL
    results = {"wal": bench_wal(scale["n_vertices"], scale["n_ops"],
                                scale["batch"]),
               "checkpoint": bench_checkpoint_and_recovery(
                   scale["n_vertices"], scale["n_ops"], scale["batch"],
                   scale["tail_ops"])}
    ratio = results["wal"][f"group_commit_{DEFAULT_GC}"]["vs_wal_off"]
    results["wal"]["default_group_commit"] = DEFAULT_GC
    results["wal"]["default_vs_wal_off"] = ratio
    if smoke:
        # CI gate (ISSUE 10 acceptance): WAL-on at the default
        # group-commit must keep >= 0.7x of WAL-off throughput
        assert ratio >= 0.7, \
            f"WAL-on at gc={DEFAULT_GC} is {ratio:.2f}x of WAL-off (< 0.7)"

    doc = {}
    if OUT.exists():
        doc = json.loads(OUT.read_text())
    doc.setdefault("bench", "durability")
    if smoke:
        doc["smoke"] = dict(stream=scale, **results)
    else:
        doc["scale"] = "full"
        doc["stream"] = scale
        doc[record] = results
    OUT.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"[OK] wrote {OUT} ({'smoke' if smoke else record})")
    return doc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--record", choices=("before", "after"),
                    default="after")
    args = ap.parse_args(argv)
    return run(smoke=args.smoke, record=args.record)


if __name__ == "__main__":
    main()
