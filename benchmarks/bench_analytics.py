"""Incremental epoch-delta analytics harness (``BENCH_analytics.json``).

Per-epoch analytics latency, from-scratch vs warm-started over the epoch
delta, through the unified ``repro.api.GraphStore`` front door on

* the 1-shard ``LocalStore`` (host advances over ``HostCsr`` views), and
* the 4-shard ``ShardedStore`` (subprocess with placeholder devices:
  warm mesh programs seeded from the previous epoch's per-shard values),

under a mixed ingest stream: a powerlaw base load, then chains of delta
epochs sized at ~0.1% / 1% / 10% of the live edge count.  Each timed
epoch runs every registered incremental algorithm BOTH ways on the same
captured handle — the harness asserts the answers agree (exactly, or
under 1e-5 for the tolerance-mode PageRank), so the artifact is a parity
check as well as a latency record.

Delta weights decrease strictly across epochs (disjoint per-epoch
ranges), so updates never increase a weight and the SSSP advance stays
on its monotone fast path; one extra tombstone epoch at the end forces
the guarded algorithms (BFS/WCC/SSSP) through their recorded fallbacks.
Every stream (base and deltas) is applied SYMMETRICALLY — the paper
treats graphs as undirected, and the WCC propagation documents that
assumption (on a one-way edge set its directional fixed point is not
the component labeling, so neither backend would agree with the
union-find advance).

Timing model: the epoch's CSR snapshot (device scan + host pull) is
built once per epoch and needed by BOTH paths — scratch algorithms
consume the device arrays, advances the host view — so it is timed
separately as ``snapshot_ms`` and charged to neither.  The delta diff
(``delta_extract_ms``) is pure incremental infrastructure paid once per
epoch and shared by every chained algorithm, so each op's
``incremental_ms`` charges an equal 1/n_ops share of it on top of its
advance; ``scratch_ms`` is the algorithm alone on the same pre-built
snapshot.

    PYTHONPATH=src python -m benchmarks.bench_analytics            # full
    PYTHONPATH=src python -m benchmarks.bench_analytics --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_analytics.json"

FULL = dict(n_vertices=8192, base_ops=65536, epochs=5)
SMOKE = dict(n_vertices=512, base_ops=4096, epochs=2)
FRACS = (0.001, 0.01, 0.1)      # delta size as a fraction of live edges


def _ops(src_id):
    """Every registered algorithm with an incremental phase. PageRank
    runs in tolerance mode (``tol`` set): the fixed-iteration default is
    path-dependent and deliberately refuses to advance."""
    from repro.api import AnalyticsOp
    return [
        AnalyticsOp("pagerank", dict(iters=200, damping=0.85, tol=1e-7)),
        AnalyticsOp("wcc", dict(max_iters=64)),
        AnalyticsOp("bfs", dict(source=src_id, max_iters=32)),
        AnalyticsOp("sssp", dict(source=src_id, max_iters=64)),
        AnalyticsOp("degree_map", {}),
        AnalyticsOp("num_edges", {}),
    ]


def _max_err(a, b) -> float:
    """Max abs difference between two normalized analytics answers."""
    if isinstance(a, dict):
        if set(a) != set(b):
            return float("inf")
        if not a:
            return 0.0
        ks = sorted(a)
        va = np.array([float(a[k]) for k in ks], np.float64)
        vb = np.array([float(b[k]) for k in ks], np.float64)
        return float(np.abs(va - vb).max())
    return abs(float(a) - float(b))


def _sym(s, d, w):
    """Symmetrize a stream: every op applied in both directions."""
    return (np.concatenate([s, d]), np.concatenate([d, s]),
            np.concatenate([w, w]))


def _delta_batch(rng, ids, n: int, k: int):
    """One delta epoch's ops (``n`` directed writes, applied as ``n/2``
    symmetric pairs): endpoints from the seen ID pool, weights in the
    epoch-k band ``[0.5, 0.9] * 0.5**k`` — strictly below every earlier
    band (base weights are >= 1.0), so an update is always a decrease
    and the monotone advances never have to refuse."""
    from repro.api import OpBatch
    lo, hi = 0.5 * 0.5 ** k, 0.9 * 0.5 ** k
    half = max(2, n // 2)
    s = ids[rng.integers(0, len(ids), half)]
    d = ids[rng.integers(0, len(ids), half)]
    w = rng.uniform(lo, hi, half).astype(np.float32)
    return OpBatch.edges(*_sym(s, d, w))


def run_chain(store, ids: np.ndarray, epochs: int, seed: int = 0):
    """Drive one store through the delta-epoch chains, timing every
    algorithm scratch vs incremental per epoch.  Returns the result
    dict for the backend section of ``BENCH_analytics.json``."""
    from repro.api import OpBatch, ReadOp

    rng = np.random.default_rng(seed + 17)
    ops = _ops(int(ids[0]))
    m_live = store.read(ReadOp("num_edges"))
    build_csr = store._csrs if hasattr(store, "_csrs") else store._csr

    # base-epoch warmup: compiles every scratch program, seeds the chain
    ep = store.capture()
    warm = {o.name: store.analytics_result(o, ep) for o in ops}
    # one untimed warmup epoch compiles the snapshot/delta pull and every
    # warm mesh program (the host advances have nothing to compile)
    k = 0
    store.apply(_delta_batch(rng, ids, max(4, int(0.001 * m_live)), k))
    k += 1
    cur = store.capture()
    store._delta(ep, cur)
    for o in ops:
        warm[o.name] = store.analytics_advance(o, warm[o.name], cur)
    prev, last_batch = cur, None

    out = {"live_edges": int(m_live), "deltas": {}}
    for frac in FRACS:
        n = max(4, int(frac * m_live))
        rows = {o.name: dict(s=[], a=[], its=[], ita=[]) for o in ops}
        dms, nch, sms = [], [], []
        for _ in range(epochs):
            last_batch = _delta_batch(rng, ids, n, k)
            k += 1
            store.apply(last_batch)
            cur = store.capture()
            t0 = time.perf_counter()
            build_csr(cur)      # shared epoch infrastructure (both paths)
            sms.append((time.perf_counter() - t0) * 1e3)
            t0 = time.perf_counter()
            d, reason = store._delta(prev, cur)
            dms.append((time.perf_counter() - t0) * 1e3)
            assert reason == "ok", reason
            nch.append(sum(x.n_changed for x in d) if isinstance(d, list)
                       else d.n_changed)
            for o in ops:
                t0 = time.perf_counter()
                rs = store.analytics_result(o, cur)
                rows[o.name]["s"].append((time.perf_counter() - t0) * 1e3)
                t0 = time.perf_counter()
                ri = store.analytics_advance(o, warm[o.name], cur)
                rows[o.name]["a"].append((time.perf_counter() - t0) * 1e3)
                assert ri.mode == "incremental", (o.name, ri.reason)
                err = _max_err(rs.value, ri.value)
                assert err <= (1e-5 if o.name == "pagerank" else 0.0), \
                    (o.name, err)
                rows[o.name]["its"].append(rs.iters)
                rows[o.name]["ita"].append(ri.iters)
                warm[o.name] = ri
            prev = cur
        dmed = float(np.median(dms))
        per_op = {}
        for o in ops:
            r = rows[o.name]
            s = float(np.median(r["s"]))
            a = float(np.median(r["a"]))
            inc = a + dmed / len(ops)
            per_op[o.name] = {
                "scratch_ms": round(s, 3), "advance_ms": round(a, 3),
                "incremental_ms": round(inc, 3),
                "speedup": round(s / max(inc, 1e-6), 2),
                "iters_scratch": int(np.median(r["its"])),
                "iters_advance": int(np.median(r["ita"]))}
        out["deltas"][f"{100 * frac:g}%"] = {
            "delta_ops": n, "delta_changed": int(np.median(nch)),
            "snapshot_ms": round(float(np.median(sms)), 3),
            "delta_extract_ms": round(dmed, 3), "epochs": epochs,
            "per_op": per_op}

    # forced-fallback epoch: tombstone the previous batch's edges (they
    # exist, so the delta genuinely records deletes) — the monotone
    # advances must refuse with a recorded reason yet still answer right
    nd = max(2, len(last_batch.src) // 4)
    store.apply(OpBatch.edges(*_sym(last_batch.src[:nd],
                                    last_batch.dst[:nd],
                                    np.zeros(nd, np.float32))))
    cur = store.capture()
    fb = {}
    for o in ops:
        ri = store.analytics_advance(o, warm[o.name], cur)
        rs = store.analytics_result(o, cur)
        err = _max_err(rs.value, ri.value)
        assert err <= (1e-5 if o.name == "pagerank" else 0.0), (o.name, err)
        fb[o.name] = {"mode": ri.mode, "reason": ri.reason}
        warm[o.name] = ri
    for guarded in ("bfs", "wcc", "sssp"):
        assert fb[guarded]["mode"] == "scratch", fb[guarded]
        assert fb[guarded]["reason"], fb[guarded]
    out["fallback_epoch"] = fb
    out["store_stats"] = {kk: store.stats[kk] for kk in (
        "defrags", "defrag_ms", "defrag_host_ms", "defrag_sync_ms",
        "tiles_scanned", "ops_dropped")}
    return out


def _base_weights(rng, n: int) -> np.ndarray:
    """Base-load weights in [1.0, 2.0] — above every delta band."""
    return rng.uniform(1.0, 2.0, n).astype(np.float32)


def bench_local(n_vertices: int, base_ops: int, epochs: int, seed: int = 0,
                smoke: bool = False):
    from benchmarks.common import edge_stream
    from repro.api import OpBatch, make_store
    # sized to the workload, not the shared GRAPH_CAPS compile cache: the
    # per-epoch snapshot scan is O(pool capacity), and this bench records
    # per-epoch latency, so an oversized pool would tax BOTH paths
    kw = (dict(n_max=4096, pool_blocks=8192) if smoke else
          dict(n_max=16384, pool_blocks=32768))
    kw.update(block_size=16, k_max=256, batch=4096,
              dmax=4096 if smoke else 8192)  # symmetric hubs: 2x degree
    store = make_store("local", key_bits=32, expected_n=n_vertices,
                       undirected=False, m_cap=16384 if smoke else 262144,
                       max_delta_frac=0.25, **kw)
    src, dst, ids = edge_stream(n_vertices, base_ops, "powerlaw", seed)
    w = _base_weights(np.random.default_rng(seed + 5), base_ops)
    src, dst, w = _sym(src, dst, w)
    B = kw["batch"]
    for lo in range(0, len(src), B):
        store.apply(OpBatch.edges(src[lo:lo + B], dst[lo:lo + B],
                                  w[lo:lo + B]))
    assert not store.graph.overflowed
    res = run_chain(store, ids, epochs, seed)
    res["shards"] = 1
    return res


def _shard_worker(n_vertices: int, base_ops: int, epochs: int,
                  n_shards: int = 4, seed: int = 0, smoke: bool = False):
    """Runs inside the subprocess (placeholder devices already forced)."""
    from benchmarks.common import edge_stream
    from repro.api import OpBatch, make_store
    store = make_store(
        "sharded", n_shards=n_shards,
        n_per_shard=4 * max(1024, n_vertices),
        expected_n=max(256, n_vertices),
        pool_blocks=max(4096, 2 * n_vertices), block_size=16,
        k_max=256, dmax=8192, batch=4096,
        m_cap=8192 if smoke else 65536, max_delta_frac=0.25)
    src, dst, ids = edge_stream(n_vertices, base_ops, "powerlaw", seed)
    w = _base_weights(np.random.default_rng(seed + 5), base_ops)
    src, dst, w = _sym(src, dst, w)
    B = store.batch
    for lo in range(0, len(src), B):
        store.apply(OpBatch.edges(src[lo:lo + B], dst[lo:lo + B],
                                  w[lo:lo + B]))
    assert store.stats["ops_dropped"] == 0, store.stats
    res = run_chain(store, ids, epochs, seed)
    res["shards"] = n_shards
    return res


def bench_sharded(n_vertices: int, base_ops: int, epochs: int,
                  n_shards: int = 4, smoke: bool = False):
    """Spawn the worker under ``--xla_force_host_platform_device_count``."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_shards}")
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{env.get('PYTHONPATH', '')}"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_analytics", "--_worker",
         json.dumps(dict(n_vertices=n_vertices, base_ops=base_ops,
                         epochs=epochs, n_shards=n_shards, smoke=smoke))],
        capture_output=True, text=True, env=env, cwd=str(ROOT), timeout=3600)
    for line in out.stdout.splitlines():
        if line.startswith("WORKER-RESULT "):
            return json.loads(line[len("WORKER-RESULT "):])
    raise RuntimeError(f"shard worker failed:\n{out.stderr[-3000:]}")


def _print_section(tag: str, res: dict):
    for fk, fr in res["deltas"].items():
        line = ", ".join(
            f"{name} {r['speedup']}x" for name, r in fr["per_op"].items())
        print(f"{tag} delta {fk} ({fr['delta_changed']} edges, extract "
              f"{fr['delta_extract_ms']} ms): {line}")
    fb = ", ".join(f"{n}:{v['mode']}({v['reason']})" if v["reason"] else
                   f"{n}:{v['mode']}" for n, v in
                   res["fallback_epoch"].items())
    print(f"{tag} tombstone epoch: {fb}")


def _gate_smoke(res: dict, tag: str):
    """CI gate: at the smallest delta, chaining must never lose — the
    amortized incremental path stays within 1.1x of scratch (+1 ms
    absolute slack, absorbing the ~free scalar ops whose scratch run is
    a single host read)."""
    small = res["deltas"][f"{100 * FRACS[0]:g}%"]["per_op"]
    for name, r in small.items():
        assert r["incremental_ms"] <= 1.1 * r["scratch_ms"] + 1.0, \
            (tag, name, r)


def run(smoke: bool = False):
    scale = SMOKE if smoke else FULL
    nv, base, epochs = scale["n_vertices"], scale["base_ops"], \
        scale["epochs"]
    one = bench_local(nv, base, epochs, smoke=smoke)
    _print_section("1-shard", one)
    four = bench_sharded(nv, base, epochs, smoke=smoke)
    _print_section("4-shard", four)
    if smoke:
        _gate_smoke(one, "one_shard")
        _gate_smoke(four, "four_shard")
    else:
        # the ROADMAP acceptance bar: warm-start PageRank/WCC at small
        # deltas beats scratch by >= 5x on the 1-shard backend
        for fk in (f"{100 * FRACS[0]:g}%", f"{100 * FRACS[1]:g}%"):
            for name in ("pagerank", "wcc"):
                sp = one["deltas"][fk]["per_op"][name]["speedup"]
                mark = "OK" if sp >= 5 else "BELOW-BAR"
                print(f"[{mark}] {name} @ {fk}: {sp}x")

    results = {"one_shard": one, "four_shard": four}
    doc = {}
    if OUT.exists():
        doc = json.loads(OUT.read_text())
    doc.setdefault("bench", "analytics")
    if smoke:
        doc["smoke"] = dict(graph=dict(scale, dist="powerlaw"), **results)
    else:
        doc["scale"] = "full"
        doc["graph"] = dict(scale, dist="powerlaw")
        doc.update(results)
    OUT.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"[OK] wrote {OUT} ({'smoke' if smoke else 'full'})")
    return doc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--_worker", help="internal: JSON kwargs for the "
                    "in-subprocess shard worker")
    args = ap.parse_args(argv)
    if args._worker:
        res = _shard_worker(**json.loads(args._worker))
        print("WORKER-RESULT " + json.dumps(res))
        return res
    return run(smoke=args.smoke)


if __name__ == "__main__":
    main()
