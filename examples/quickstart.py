"""Quickstart: RadixGraph in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.radixgraph import RadixGraph
from repro import analytics as A
import jax.numpy as jnp

# a dynamic graph over non-contiguous 32-bit IDs (UUID-style)
g = RadixGraph(n_max=4096, key_bits=32, expected_n=1000, batch=1024,
               pool_blocks=16384, block_size=16, undirected=True)
print("SORT fanouts chosen by the optimizer:", g.config.fanout_bits)

rng = np.random.default_rng(0)
ids = rng.choice(2**32, 1000, replace=False).astype(np.uint64)

# stream edge updates: inserts, weight updates, deletions — O(1) amortized
src, dst = rng.choice(ids, 8000), rng.choice(ids, 8000)
w = rng.uniform(0.5, 2.0, 8000).astype(np.float32)
g.add_edges(src, dst, w)
print(f"{g.num_vertices} vertices, {g.num_edges} edges, "
      f"{g.memory_bytes()/2**20:.2f} MiB")

v0 = g.checkpoint_version()                      # MVCC snapshot
g.delete_edges(src[:4000], dst[:4000])           # tombstone appends
g.update_edges(src[4000:5000], dst[4000:5000],
               np.full(1000, 9.0, np.float32))   # weight updates
print("after mixed updates:", g.num_edges, "edges")

# reads: get-neighbors (compaction-style scan, O(d))
nbr_ids, nbr_w = g.neighbors([int(ids[0])])[0]
print(f"vertex {ids[0]} has {len(nbr_ids)} live neighbors")

# time travel: read the graph as of version v0
old_ids, _ = g.neighbors([int(ids[0])], read_ts=v0)[0]
print(f"...and had {len(old_ids)} at version {v0}")

# analytics on a consistent snapshot (CSR over the edge chain)
snap = g.snapshot()
off = g.lookup(ids[:1])
pr = A.pagerank(snap, iters=20)
depth = A.bfs(snap, jnp.int32(int(off[0])))
print(f"pagerank sum={float(jnp.sum(pr)):.3f}, "
      f"BFS reached {int(jnp.sum(depth >= 0))} vertices")
print("OK")
