"""Quickstart: the unified GraphStore API in 60 seconds.

ONE driving script, TWO storage backends — the eager single-shard
RadixGraph and the mesh-sharded engine. Only the construction config
differs; every apply/read/analytics line below runs unchanged on both:

  PYTHONPATH=src python examples/quickstart.py            # local backend
  PYTHONPATH=src python examples/quickstart.py sharded    # 1-shard mesh
"""
import sys

import numpy as np

from repro.api import AnalyticsOp, OpBatch, ReadOp, make_store

CONFIGS = {
    "local": dict(n_max=4096, key_bits=32, expected_n=1000, batch=1024,
                  pool_blocks=16384, block_size=16, undirected=True),
    "sharded": dict(n_shards=1, n_per_shard=4096, expected_n=1000,
                    batch=1024, pool_blocks=16384, block_size=16,
                    undirected=True),
}
backend = sys.argv[1] if len(sys.argv) > 1 else "local"
store = make_store(backend, **CONFIGS[backend])
print(f"backend: {store.backend}")

# a dynamic graph over non-contiguous 32-bit IDs (UUID-style)
rng = np.random.default_rng(0)
ids = rng.choice(2**32, 1000, replace=False).astype(np.uint64)

# stream edge updates: inserts, weight updates, deletions — O(1) amortized
src, dst = rng.choice(ids, 8000), rng.choice(ids, 8000)
w = rng.uniform(0.5, 2.0, 8000).astype(np.float32)
res = store.apply(OpBatch.edges(src, dst, w))
print(f"{store.read(ReadOp('num_vertices'))} vertices, "
      f"{store.read(ReadOp('num_edges'))} edges "
      f"(dropped {res.dropped})")

v0 = store.capture()                              # O(1) MVCC epoch handle
store.apply(OpBatch.edges(src[:4000], dst[:4000],
                          np.zeros(4000, np.float32)))   # tombstone appends
store.apply(OpBatch.edges(src[4000:5000], dst[4000:5000],
                          np.full(1000, 9.0, np.float32)))  # weight updates
print("after mixed updates:", store.read(ReadOp("num_edges")), "edges")

# reads: presence, degrees, get-neighbors (compaction-style scan, O(d))
assert store.read(ReadOp("lookup", ids=ids[:4])).all()
deg = store.read(ReadOp("degree", ids=ids[:4]))
nbr_ids, nbr_w = store.read(ReadOp("neighbors", ids=ids[:1]))[0]
print(f"vertex {ids[0]} has {len(nbr_ids)} live neighbors "
      f"(degrees {deg.tolist()})")

# time travel: the captured epoch still answers — functional states ARE
# the paper's MVCC versioned arrays
old_deg = store.read(ReadOp("degree", ids=ids[:1]), at=v0)[0]
print(f"...and had {old_deg} at the captured epoch")

# analytics through the registry: identical results on either backend
pr = store.analytics(AnalyticsOp("pagerank", {"iters": 20}))
depth = store.analytics(AnalyticsOp("bfs", {"source": int(src[0])}))
comp = store.analytics(AnalyticsOp("wcc"))
print(f"pagerank sum={sum(pr.values()):.3f}, "
      f"BFS reached {sum(1 for d in depth.values() if d >= 0)} vertices, "
      f"{len(set(comp.values()))} components")
print("OK")
