"""End-to-end driver: train an LM for a few hundred steps with checkpointing,
optionally streaming its tokens out of a live RadixGraph (random walks).

Default is a ~100M-param qwen2.5-family config scaled for CPU wall clocks;
pass --full-100m on real hardware for the genuine 100M run.

  PYTHONPATH=src python examples/train_lm.py            # quick CPU run
  PYTHONPATH=src python examples/train_lm.py --graph    # graph-fed corpus
"""
import argparse
import sys

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--graph", action="store_true",
                    help="draw training tokens from a live RadixGraph")
    ap.add_argument("--full-100m", action="store_true",
                    help="train the real ~100M config (use on TPU/large CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    ckpt = args.ckpt_dir or ("/tmp/repro_lm_ckpt_graph" if args.graph
                             else "/tmp/repro_lm_ckpt")

    argv = ["--arch", "qwen2.5-3b", "--steps", str(args.steps),
            "--ckpt-dir", ckpt, "--ckpt-every", "100",
            "--lr", "1e-3", "--data", "graph" if args.graph else "synthetic"]
    if args.full_100m:
        # ~100M params: 12 x 768 with the qwen2.5 block (run on real HW)
        import repro.configs.qwen2_5_3b as q
        q.SMOKE = q.CONFIG.scaled(layers=12, d_model=768, n_heads=12,
                                  kv_heads=2, d_ff=2048, vocab=32000,
                                  param_dtype="float32",
                                  compute_dtype="float32")
        argv += ["--smoke", "--batch", "8", "--seq", "512"]
    else:
        argv += ["--smoke", "--batch", "16", "--seq", "64"]
    losses = T.main(argv)
    if not losses:
        print("OK (already trained to --steps; delete the ckpt dir to rerun)")
        return
    import numpy as np
    head = float(np.mean(losses[:10]))
    tail = float(np.mean(losses[-10:]))
    if args.graph:
        # random-walk corpora over random graphs are near-iid: require
        # non-divergence, not a visible drop, at short step counts
        assert tail <= head + 0.05, (head, tail)
    else:
        assert tail < head, (head, tail)
    print(f"OK: loss {head:.3f} -> {tail:.3f} (mean-of-10) over "
          f"{len(losses)} steps")


if __name__ == "__main__":
    main()
