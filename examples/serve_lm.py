"""Batched serving with continuous batching + the RadixKV snapshot-log block
manager (the paper's edge-array lifecycle on KV cache blocks).

  PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import get_arch
from repro.models.api import build_model
from repro.serve import ServeEngine

cfg = get_arch("internlm2-1.8b").SMOKE
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

eng = ServeEngine(model, params, slots=4, smax=96, kv_blocks=256,
                  block_tokens=8)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab, int(n)).astype(np.int32)
           for n in rng.integers(4, 20, 12)]

results = eng.run(prompts, max_new=10)
for i in sorted(results)[:5]:
    print(f"prompt {i} ({len(prompts[i])} toks) -> {results[i]}")
print(f"served {len(results)} requests; RadixKV: "
      f"{eng.kv.defrags} defrags, {eng.kv.overflow} admission overflows, "
      f"utilization {eng.kv.utilization:.2f}")
assert len(results) == len(prompts)
print("OK")
