"""Streaming ingestion + concurrent analytics on MVCC snapshots.

The writer ingests update waves; after each wave an analytics "reader" runs
PageRank/WCC on a consistent retained version while new writes proceed —
the paper's Fig. 7 / §4.5 workload in functional form.

  PYTHONPATH=src python examples/streaming_analytics.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro import analytics as A
from repro.core.radixgraph import RadixGraph

g = RadixGraph(n_max=8192, key_bits=32, expected_n=2000, batch=2048,
               pool_blocks=32768, block_size=16, undirected=True)
rng = np.random.default_rng(1)
ids = rng.choice(2**32, 2000, replace=False).astype(np.uint64)

versions = []
for wave in range(6):
    src, dst = rng.choice(ids, 4000), rng.choice(ids, 4000)
    w = rng.uniform(0.5, 2.0, 4000).astype(np.float32)
    w[rng.random(4000) < 0.2] = 0.0   # 20% deletions
    t0 = time.perf_counter()
    g.apply_ops(src, dst, w)
    ts = g.checkpoint_version()
    dt = time.perf_counter() - t0
    print(f"wave {wave}: ingested 8000 directed ops in {dt*1e3:.0f} ms "
          f"-> version {ts}, {g.num_edges} live edges")

# analytics over the retained versions (old states stay readable — MVCC):
# snapshot_at resolves each timestamp against the retained version that
# still holds its history, even after later compactions/defrags
for label, vts in g.retained_versions[::2]:
    snap = g.snapshot_at(vts)
    pr = A.pagerank(snap, iters=10)
    wcc = A.wcc(snap)
    ncomp = len(set(np.asarray(wcc)[np.asarray(wcc) >= 0].tolist()))
    print(f"version {label}: m={int(snap.m)}, pr_sum="
          f"{float(jnp.sum(pr)):.3f}, components={ncomp}")

# retained versions are device memory: release the ones we're done with
for label, _ in g.retained_versions[:-1]:
    g.release_version(label)
print(f"retained after release: {g.retained_versions}")
print("OK")
