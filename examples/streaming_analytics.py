"""Streaming ingestion + concurrent analytics on captured MVCC epochs.

The writer ingests update waves through a ``GraphStore``; after each wave
an O(1) ``capture()`` publishes the immutable state, and an analytics
"reader" later runs PageRank/WCC on those consistent epochs while new
writes proceed — the paper's Fig. 7 / §4.5 workload in functional form,
backend-agnostic:

  PYTHONPATH=src python examples/streaming_analytics.py            # local
  PYTHONPATH=src python examples/streaming_analytics.py sharded
"""
import sys
import time

import numpy as np

from repro.api import AnalyticsOp, OpBatch, ReadOp, make_store

CONFIGS = {
    "local": dict(n_max=8192, key_bits=32, expected_n=2000, batch=2048,
                  pool_blocks=32768, block_size=16, undirected=True),
    "sharded": dict(n_shards=1, n_per_shard=8192, expected_n=2000,
                    batch=2048, pool_blocks=32768, block_size=16,
                    undirected=True),
}
backend = sys.argv[1] if len(sys.argv) > 1 else "local"
store = make_store(backend, **CONFIGS[backend])
rng = np.random.default_rng(1)
ids = rng.choice(2**32, 2000, replace=False).astype(np.uint64)

epochs = []
for wave in range(6):
    src, dst = rng.choice(ids, 4000), rng.choice(ids, 4000)
    w = rng.uniform(0.5, 2.0, 4000).astype(np.float32)
    w[rng.random(4000) < 0.2] = 0.0   # 20% deletions
    t0 = time.perf_counter()
    store.apply(OpBatch.edges(src, dst, w))
    epochs.append(store.capture())
    dt = time.perf_counter() - t0
    print(f"wave {wave}: ingested 8000 directed ops in {dt*1e3:.0f} ms "
          f"-> epoch {epochs[-1].seq}, "
          f"{store.read(ReadOp('num_edges'))} live edges")

# analytics over the captured epochs (old states stay readable — MVCC):
# every epoch handle answers the same AnalyticsOps as the live state
for h in epochs[::2]:
    pr = store.analytics(AnalyticsOp("pagerank", {"iters": 10}), at=h)
    comp = store.analytics(AnalyticsOp("wcc"), at=h)
    print(f"epoch {h.seq}: m={store.read(ReadOp('num_edges'), at=h)}, "
          f"pr_sum={sum(pr.values()):.3f}, "
          f"components={len(set(comp.values()))}")

# epoch handles retain device memory: drop the ones we're done with
keep = epochs[-1]
epochs.clear()
print(f"retained epoch: {keep.seq}")
print("OK")
